//! Device-resident KV-cache handles.
//!
//! A `KvSet` owns the `2 * n_layers` PJRT buffers of one cache instance
//! plus the host-side bookkeeping the lockstep cache discipline needs
//! (see `python/compile/model.py` docstring): the physical write frontier,
//! per-slot logical positions, and the per-slot validity bitmask that
//! marks which physical positions are attendable (clean tokens) vs junk
//! (block overshoot past a step boundary / PAD slots).

use xla::PjRtBuffer;

use crate::runtime::blocks::{BlockTable, PoolExhausted, PoolStats, SharedPool};

/// Device KV cache + host bookkeeping for a batch of beam slots.
pub struct KvSet {
    /// `[l0.k, l0.v, l1.k, l1.v, ...]`, each `[batch, heads, cache_len, hd]`.
    pub bufs: Vec<PjRtBuffer>,
    pub batch: usize,
    pub cache_len: usize,
    /// Lockstep physical write frontier (same for every slot).
    pub pos_phys: usize,
    /// Per-slot logical sequence length (RoPE positions).
    pub pos_log: Vec<i32>,
    /// Per-slot validity bitmask, row-major `[batch, cache_len]`.
    pub valid: Vec<i32>,
    /// Paged allocation (block tables over the shard's shared pool);
    /// `None` runs the dense fixed-length discipline unchanged.
    pub pages: Option<PagedKv>,
    /// Reusable gather scratch for `permute_bookkeeping` (beam prunes run
    /// at `batch * cache_len` cost per call; cloning `valid` there showed
    /// up on the hot path). Capacity persists across calls.
    scratch_valid: Vec<i32>,
    scratch_log: Vec<i32>,
}

/// Paged extension of one cache: a block table per slot over the shard's
/// shared [`crate::runtime::blocks::BlockPool`]. Slot edits — beam
/// permute, gang merge, two-tier resize — fork tables (refcount bumps)
/// instead of moving device rows, and a rejected beam's blocks return to
/// the pool the moment [`KvSet::free_slot`] runs. Dropping the cache
/// releases every table, so pool conservation holds on all exit paths.
pub struct PagedKv {
    pool: SharedPool,
    tables: Vec<BlockTable>,
    /// Slots whose beam died: their blocks are back in the pool and they
    /// reserve nothing at future frontier advances.
    dead: Vec<bool>,
    /// Block-native mode: the attention programs index these tables
    /// *directly* (`decode_blocktab_bN` / `score_blocktab_bN`), so each
    /// slot writes at its own frontier (= its table's token length) and
    /// merge/split/compact are pure table edits with no device call.
    /// `false` is the gather-bracketed mode where tables are host-side
    /// accounting only.
    device: bool,
}

impl PagedKv {
    fn new(pool: SharedPool, batch: usize) -> Self {
        PagedKv {
            pool,
            tables: (0..batch).map(|_| BlockTable::new()).collect(),
            dead: vec![false; batch],
            device: false,
        }
    }

    pub fn pool(&self) -> &SharedPool {
        &self.pool
    }

    pub fn table(&self, slot: usize) -> &BlockTable {
        &self.tables[slot]
    }

    pub fn is_dead(&self, slot: usize) -> bool {
        self.dead[slot]
    }

    /// Blocks currently held across every live slot.
    pub fn blocks_held(&self) -> usize {
        self.tables.iter().map(|t| t.blocks().len()).sum()
    }

    /// Grow every live slot's table to cover `[0, upto)`. All-or-nothing
    /// across slots: on exhaustion the slots already grown roll back, so
    /// the caller can retry after other work frees blocks (or surface
    /// backpressure) without leaking.
    fn reserve_all(&mut self, upto: usize) -> Result<(), PoolExhausted> {
        let mut pool = self.pool.borrow_mut();
        let prior: Vec<usize> = self.tables.iter().map(|t| t.len_tokens()).collect();
        for slot in 0..self.tables.len() {
            if self.dead[slot] {
                continue;
            }
            if let Err(e) = self.tables[slot].reserve(&mut pool, upto) {
                for s in 0..slot {
                    self.tables[s].truncate(&mut pool, prior[s]);
                }
                return Err(e);
            }
        }
        Ok(())
    }

    /// Block-native frontier growth: every live slot's table grows by `n`
    /// tokens *from its own length*. Slot frontiers diverge inside a
    /// transient merged gang cache (each member kept its own write clock),
    /// so a lockstep `reserve_all(pos_phys + n)` would under-reserve the
    /// widest member. Same all-or-nothing rollback contract.
    fn reserve_step(&mut self, n: usize) -> Result<(), PoolExhausted> {
        let mut pool = self.pool.borrow_mut();
        let prior: Vec<usize> = self.tables.iter().map(|t| t.len_tokens()).collect();
        for slot in 0..self.tables.len() {
            if self.dead[slot] {
                continue;
            }
            if let Err(e) = self.tables[slot].reserve(&mut pool, prior[slot] + n) {
                for s in 0..slot {
                    self.tables[s].truncate(&mut pool, prior[s]);
                }
                return Err(e);
            }
        }
        Ok(())
    }

    /// Flatten the tables into the `[batch, nbl]` i32 operand the
    /// block-native programs take. Rows pad with `trash` — the pool's
    /// spare row that absorbs dead-slot and overshoot writes and is never
    /// attended (the frontier mask sits below any padded entry).
    pub fn operand(&self, nbl: usize, trash: i32) -> Vec<i32> {
        let batch = self.tables.len();
        let mut out = vec![trash; batch * nbl];
        for slot in 0..batch {
            if self.dead[slot] {
                continue;
            }
            let blocks = self.tables[slot].blocks();
            assert!(blocks.len() <= nbl, "table of {} blocks exceeds operand {nbl}", blocks.len());
            for (j, &b) in blocks.iter().enumerate() {
                out[slot * nbl + j] = b as i32;
            }
        }
        out
    }
}

impl Drop for PagedKv {
    fn drop(&mut self) {
        let mut pool = self.pool.borrow_mut();
        for t in &mut self.tables {
            t.release_all(&mut pool);
        }
    }
}

/// A host-computed re-compaction of one cache: for every slot, the gather
/// index matrix packs its valid (attendable) positions down to a dense
/// prefix, in their original order, so the junk gap under the lockstep
/// frontier is reclaimed. Built by [`KvSet::compact_plan`] (pure), applied
/// to the bookkeeping with [`KvSet::apply_compact`] after the matching
/// `compact_bN` device gather ran.
#[derive(Debug, Clone)]
pub struct CompactPlan {
    /// Row-major `[batch, cache_len]` source position per (slot, dest);
    /// dest positions past a slot's dense length replay position 0 (junk —
    /// the packed validity row masks them out).
    pub idx: Vec<i32>,
    /// Post-compaction lockstep frontier: the max dense length over slots.
    pub new_frontier: usize,
    /// Physical positions reclaimed (`pos_phys - new_frontier`).
    pub reclaimed: usize,
}

impl KvSet {
    pub fn new(bufs: Vec<PjRtBuffer>, batch: usize, cache_len: usize) -> Self {
        KvSet {
            bufs,
            batch,
            cache_len,
            pos_phys: 0,
            pos_log: vec![0; batch],
            valid: vec![0; batch * cache_len],
            pages: None,
            scratch_valid: Vec::new(),
            scratch_log: Vec::new(),
        }
    }

    /// Whether this cache runs paged (block-table) allocation.
    pub fn paged(&self) -> bool {
        self.pages.is_some()
    }

    /// Whether this cache is block-native: the device programs index its
    /// block tables directly, so merge/split/compact are table edits.
    pub fn block_native(&self) -> bool {
        self.pages.as_ref().is_some_and(|p| p.device)
    }

    /// Attach paged allocation: one block table per slot, covering the
    /// current physical frontier. All-or-nothing — on pool exhaustion the
    /// cache stays dense (`pages` remains `None`) and nothing leaks.
    pub fn attach_pages(&mut self, pool: SharedPool) -> Result<(), PoolExhausted> {
        let mut pages = PagedKv::new(pool, self.batch);
        pages.reserve_all(self.pos_phys)?;
        self.pages = Some(pages);
        Ok(())
    }

    /// Attach *block-native* paged allocation: every slot gets a freshly
    /// allocated table covering the current frontier (no block sharing —
    /// slots write divergent tokens at the shared frontier block, so the
    /// CoW forks the gather-bracketed mode uses would collide). The device
    /// half — scattering the dense prefill into the pool rows — is the
    /// engine's `adopt_blocktab_bN` call.
    pub fn attach_native_tables(&mut self, pool: SharedPool) -> Result<(), PoolExhausted> {
        let mut pages = PagedKv::new(pool, self.batch);
        pages.device = true;
        pages.reserve_all(self.pos_phys)?;
        self.pages = Some(pages);
        Ok(())
    }

    /// Reserve pool blocks for the next block write of `n` positions
    /// (no-op on a dense cache). Called *before* `advance_frontier`; an
    /// `Err` means the pool cannot cover the write and the caller must
    /// back off (queueing / 503), with the cache untouched. Block-native
    /// caches grow each live slot from its *own* frontier (slot clocks
    /// diverge inside a merged gang cache); gather-bracketed caches grow
    /// lockstep to `pos_phys + n`.
    pub fn reserve_frontier(&mut self, n: usize) -> Result<(), PoolExhausted> {
        let target = self.pos_phys + n;
        if let Some(p) = self.pages.as_mut() {
            if p.device {
                p.reserve_step(n)?;
            } else {
                p.reserve_all(target)?;
            }
        }
        Ok(())
    }

    /// Per-slot write frontiers for the block-native programs' `frontier`
    /// operand: a live slot writes (and attends) at its table's token
    /// length; dead slots report 0, which masks every position out.
    pub fn slot_frontiers(&self) -> Vec<i32> {
        let p = self.pages.as_ref().expect("slot_frontiers needs a paged cache");
        (0..self.batch)
            .map(|s| if p.dead[s] { 0 } else { p.tables[s].len_tokens() as i32 })
            .collect()
    }

    /// Flatten the block tables into the `[batch, nbl]` i32 operand the
    /// block-native programs take. Rows pad with `trash` — the pool's
    /// spare row that absorbs dead-slot and overshoot writes and is never
    /// attended (the frontier mask sits below any padded entry).
    pub fn table_operand(&self, nbl: usize, trash: i32) -> Vec<i32> {
        let p = self.pages.as_ref().expect("table_operand needs a paged cache");
        p.operand(nbl, trash)
    }

    /// Return a dead beam's blocks to the pool — the early-rejection
    /// reclaim, which runs in the same scheduler tick as the rejection
    /// itself. The slot's validity row becomes all-junk (nobody attends a
    /// freed slot again); dense caches only take the validity edit.
    pub fn free_slot(&mut self, slot: usize) {
        assert!(slot < self.batch, "slot {slot} out of range {}", self.batch);
        let Some(p) = self.pages.as_mut() else { return };
        if !p.dead[slot] {
            let mut pool = p.pool.borrow_mut();
            p.tables[slot].release_all(&mut pool);
            p.dead[slot] = true;
        }
        let row = slot * self.cache_len;
        self.valid[row..row + self.cache_len].fill(0);
    }

    /// Point-in-time pool gauges (`None` on a dense cache).
    pub fn pool_stats(&self) -> Option<PoolStats> {
        self.pages.as_ref().map(|p| p.pool.borrow().stats())
    }

    /// Paged half of a broadcast b=1 → n: the replicas' tables are forks
    /// of slot 0's — shared blocks, refcount bumps, no device copy.
    pub fn broadcast_pages(&self, n: usize) -> Option<PagedKv> {
        let p = self.pages.as_ref()?;
        let pool = p.pool.clone();
        let mut tables = Vec::with_capacity(n);
        {
            let mut pool_ref = pool.borrow_mut();
            for _ in 0..n {
                tables.push(p.tables[0].fork(&mut pool_ref));
            }
        }
        Some(PagedKv { pool, tables, dead: vec![false; n], device: p.device })
    }

    /// Paged half of a gather/resize along `idx` (same indexing as
    /// `permute_bookkeeping`, but producing a new cache's tables): forks
    /// share blocks with the sources by refcount.
    pub fn gather_pages(&self, idx: &[i32]) -> Option<PagedKv> {
        let p = self.pages.as_ref()?;
        let pool = p.pool.clone();
        let mut tables = Vec::with_capacity(idx.len());
        let mut dead = Vec::with_capacity(idx.len());
        {
            let mut pool_ref = pool.borrow_mut();
            for &src in idx {
                let src = src as usize;
                assert!(src < self.batch, "gather index {src} out of range");
                tables.push(p.tables[src].fork(&mut pool_ref));
                dead.push(p.dead[src]);
            }
        }
        Some(PagedKv { pool, tables, dead, device: p.device })
    }

    /// Block-native half of a gather/resize: *freshly allocated* tables
    /// sized like the sources along `idx` — no sharing, because gathered
    /// children immediately write divergent tokens into their frontier
    /// blocks and a refcount fork would make those writes collide. The
    /// device half (row copies through the pool) is the engine's
    /// `copy_blocktab_bN` call. All-or-nothing on exhaustion.
    pub fn gather_fresh_tables(&self, idx: &[i32]) -> Result<PagedKv, PoolExhausted> {
        let p = self.pages.as_ref().expect("gather_fresh_tables needs a paged cache");
        let pool = p.pool.clone();
        let mut tables: Vec<BlockTable> = Vec::with_capacity(idx.len());
        let mut dead = Vec::with_capacity(idx.len());
        {
            let mut pool_ref = pool.borrow_mut();
            for &src in idx {
                let src = src as usize;
                assert!(src < self.batch, "gather index {src} out of range");
                let mut t = BlockTable::new();
                if !p.dead[src] {
                    if let Err(e) = t.reserve(&mut pool_ref, p.tables[src].len_tokens()) {
                        for ft in &mut tables {
                            ft.release_all(&mut pool_ref);
                        }
                        return Err(e);
                    }
                }
                tables.push(t);
                dead.push(p.dead[src]);
            }
        }
        Ok(PagedKv { pool, tables, dead, device: true })
    }

    /// Paged half of a gang merge: the union cache's tables fork the
    /// members' along the same union index as [`KvSet::merge_bookkeeping`]
    /// — block-table concatenation instead of a device-wide gather.
    /// `None` unless both members are paged (they share the shard pool).
    pub fn merge_pages(a: &KvSet, b: &KvSet, idx: &[i32]) -> Option<PagedKv> {
        let (pa, pb) = (a.pages.as_ref()?, b.pages.as_ref()?);
        let pool = pa.pool.clone();
        let mut tables = Vec::with_capacity(idx.len());
        let mut dead = Vec::with_capacity(idx.len());
        {
            let mut pool_ref = pool.borrow_mut();
            for &i in idx {
                let i = i as usize;
                let (src, row) = if i < a.batch {
                    (pa, i)
                } else {
                    assert!(i - a.batch < b.batch, "merge index {i} out of union range");
                    (pb, i - a.batch)
                };
                tables.push(src.tables[row].fork(&mut pool_ref));
                dead.push(src.dead[row]);
            }
        }
        Some(PagedKv { pool, tables, dead, device: pa.device })
    }

    /// Block-native gang merge: build the union cache as *pure table
    /// edits* — live member slots fork their tables (refcount bumps, zero
    /// device work), padding slots become dead slots with empty tables.
    /// Padding detection: the `merge_index` contract packs each live slot
    /// exactly once, so any repeat occurrence of an index is a pad. The
    /// dense path replays slot 0's rows for pads, which is harmless when
    /// the device write lands at a lockstep frontier — but a block-native
    /// pad forking slot 0's table would *write into slot 0's frontier
    /// block*, so pads here own nothing and write to the pool's trash row
    /// instead. `None` unless both members are block-native.
    pub fn merge_tables(a: &KvSet, b: &KvSet, idx: &[i32]) -> Option<KvSet> {
        if !a.block_native() || !b.block_native() {
            return None;
        }
        let (pa, pb) = (a.pages.as_ref()?, b.pages.as_ref()?);
        let s = a.cache_len;
        let (pos, mut pos_log, mut valid) = KvSet::merge_bookkeeping(a, b, idx);
        let pool = pa.pool.clone();
        let mut tables = Vec::with_capacity(idx.len());
        let mut dead = Vec::with_capacity(idx.len());
        let mut seen = vec![false; a.batch + b.batch];
        {
            let mut pool_ref = pool.borrow_mut();
            for (d, &i) in idx.iter().enumerate() {
                let i = i as usize;
                if seen[i] {
                    // padding replay: a dead slot that attends nothing and
                    // writes only to the trash row
                    tables.push(BlockTable::new());
                    dead.push(true);
                    pos_log[d] = 0;
                    valid[d * s..(d + 1) * s].fill(0);
                    continue;
                }
                seen[i] = true;
                let (src, row) =
                    if i < a.batch { (pa, i) } else { (pb, i - a.batch) };
                tables.push(src.tables[row].fork(&mut pool_ref));
                dead.push(src.dead[row]);
            }
        }
        let mut kv = KvSet::new(Vec::new(), idx.len(), s);
        kv.pos_phys = pos;
        kv.pos_log = pos_log;
        kv.valid = valid;
        kv.pages = Some(PagedKv { pool, tables, dead, device: true });
        Some(kv)
    }

    /// Block-native gang split: carve member slots `[start, start + n)`
    /// back out of a merged cache as table forks — the inverse of
    /// [`KvSet::merge_tables`], again zero device work. The member's
    /// frontier is its own live slots' table length (all equal: a member
    /// entered the merge lockstep and every live slot advanced by the same
    /// block writes), *not* the union max — so the union gap the lockstep
    /// merge used to create never exists here.
    pub fn split_tables(&self, start: usize, n: usize) -> Option<KvSet> {
        let p = self.pages.as_ref()?;
        if !p.device {
            return None;
        }
        assert!(start + n <= self.batch, "split range {start}+{n} out of batch {}", self.batch);
        let s = self.cache_len;
        let mut tables = Vec::with_capacity(n);
        let mut dead = Vec::with_capacity(n);
        let mut frontier = 0usize;
        {
            let mut pool_ref = p.pool.borrow_mut();
            for i in start..start + n {
                tables.push(p.tables[i].fork(&mut pool_ref));
                dead.push(p.dead[i]);
                if !p.dead[i] {
                    frontier = frontier.max(p.tables[i].len_tokens());
                }
            }
        }
        let mut kv = KvSet::new(Vec::new(), n, s);
        kv.pos_phys = frontier;
        kv.pos_log = self.pos_log[start..start + n].to_vec();
        kv.valid = self.valid[start * s..(start + n) * s].to_vec();
        kv.pages = Some(PagedKv { pool: p.pool.clone(), tables, dead, device: true });
        Some(kv)
    }

    /// Mark `[start, start+n)` physical positions of `slot` attendable and
    /// advance its logical position by `n`.
    pub fn commit(&mut self, slot: usize, start: usize, n: usize) {
        assert!(slot < self.batch, "slot {slot} out of range {}", self.batch);
        assert!(start + n <= self.cache_len, "cache overflow: {}+{n} > {}", start, self.cache_len);
        let row = slot * self.cache_len;
        for p in start..start + n {
            self.valid[row + p] = 1;
        }
        self.pos_log[slot] += n as i32;
    }

    /// Advance the lockstep frontier after a block write of `n` positions.
    pub fn advance_frontier(&mut self, n: usize) {
        self.pos_phys += n;
        assert!(
            self.pos_phys <= self.cache_len,
            "physical frontier {} past cache_len {}",
            self.pos_phys,
            self.cache_len
        );
    }

    /// Remaining physical capacity.
    pub fn remaining(&self) -> usize {
        self.cache_len - self.pos_phys
    }

    /// Attendable positions per slot (dense length after a compaction).
    pub fn valid_count(&self, slot: usize) -> usize {
        let row = slot * self.cache_len;
        self.valid[row..row + self.cache_len].iter().filter(|&&v| v != 0).count()
    }

    /// One-pass junk statistics: `(spent, valid_total, max_dense)`. The
    /// compaction triggers and the utilization gauge each need all three,
    /// and they run per scheduler tick on the hot path — one fused scan
    /// of the bitmask (the same order of work as the bitmask upload every
    /// decode/score call already pays) instead of one per derived value.
    pub fn junk_stats(&self) -> (usize, usize, usize) {
        let spent = self.batch * self.pos_phys;
        let mut valid_total = 0usize;
        let mut max_dense = 0usize;
        for slot in 0..self.batch {
            let c = self.valid_count(slot);
            valid_total += c;
            max_dense = max_dense.max(c);
        }
        (spent, valid_total, max_dense)
    }

    /// Junk share of the spent cache: positions below the lockstep
    /// frontier that no slot may attend (block overshoot, PAD, dead-slot
    /// rows), over all spent positions. 0.0 on a fresh cache.
    pub fn junk_fraction(&self) -> f64 {
        let (spent, valid_total, _) = self.junk_stats();
        if spent == 0 {
            return 0.0;
        }
        (spent - valid_total) as f64 / spent as f64
    }

    /// Last attendable position of a slot, exclusive (0 when the slot
    /// attends nothing). The block-native truncation target: everything at
    /// or past the max tail over slots is junk in *every* slot.
    fn tail_len(&self, slot: usize) -> usize {
        let row = slot * self.cache_len;
        (0..self.cache_len)
            .rev()
            .find(|&p| self.valid[row + p] != 0)
            .map_or(0, |p| p + 1)
    }

    /// Physical positions a re-compaction would reclaim — mode-aware,
    /// because the two compaction mechanisms reclaim different things. The
    /// device-gather repack packs each slot's valid positions dense, so
    /// the frontier drops to the max *dense* length; the block-native
    /// table truncation keeps interior holes (no rows move) and only
    /// reclaims the common junk tail, so the frontier drops to the max
    /// *tail* length. Reporting the repack number on a block-native cache
    /// would promise reclaim the truncation cannot deliver and livelock
    /// the coordinator's rescue trigger.
    pub fn reclaimable(&self) -> usize {
        if self.block_native() {
            let tail = (0..self.batch).map(|s| self.tail_len(s)).max().unwrap_or(0);
            self.pos_phys.saturating_sub(tail)
        } else {
            let (_, _, max_dense) = self.junk_stats();
            self.pos_phys.saturating_sub(max_dense)
        }
    }

    /// Block-native re-compaction: truncate every live slot's table to the
    /// common max tail length and drop the frontier to match — a pure
    /// table edit (tail blocks release by refcount), no device gather, no
    /// validity repack. Uniform across slots because the lockstep commit
    /// discipline (`decode_absorb` commits at `pos_phys - decode_block`
    /// for every pending slot) requires live tables to share one frontier
    /// outside transient merges. Returns `(positions_reclaimed,
    /// blocks_freed)`; `(0, 0)` when the junk tail is empty.
    pub fn compact_tables(&mut self) -> (usize, usize) {
        assert!(self.block_native(), "compact_tables is the block-native path");
        let target = (0..self.batch).map(|s| self.tail_len(s)).max().unwrap_or(0);
        let reclaimed = self.pos_phys.saturating_sub(target);
        if reclaimed == 0 {
            return (0, 0);
        }
        let p = self.pages.as_mut().expect("block_native implies pages");
        let mut pool = p.pool.borrow_mut();
        let free_before = pool.free_blocks();
        for slot in 0..p.tables.len() {
            if !p.dead[slot] {
                let keep = target.min(p.tables[slot].len_tokens());
                p.tables[slot].truncate(&mut pool, keep);
            }
        }
        let freed = pool.free_blocks() - free_before;
        drop(pool);
        self.pos_phys = target;
        (reclaimed, freed)
    }

    /// Plan a re-compaction (pure — bookkeeping is untouched until
    /// [`KvSet::apply_compact`]). Each slot's valid positions pack down to
    /// a dense prefix *in their original order*, which is what keeps the
    /// device gather semantically invisible: the attendable (position ->
    /// K/V) sequence every future attention call reads is unchanged, only
    /// the junk holes between entries disappear. Returns `None` when
    /// nothing would be reclaimed.
    pub fn compact_plan(&self) -> Option<CompactPlan> {
        let s = self.cache_len;
        let mut idx = vec![0i32; self.batch * s];
        let mut max_dense = 0usize;
        for slot in 0..self.batch {
            let row = slot * s;
            let mut dense = 0usize;
            for p in 0..s {
                if self.valid[row + p] != 0 {
                    idx[row + dense] = p as i32;
                    dense += 1;
                }
            }
            max_dense = max_dense.max(dense);
        }
        let reclaimed = self.pos_phys.saturating_sub(max_dense);
        if reclaimed == 0 {
            return None;
        }
        Some(CompactPlan { idx, new_frontier: max_dense, reclaimed })
    }

    /// Apply a plan to the host bookkeeping after the device gather ran:
    /// validity rows become dense prefixes, the lockstep frontier drops to
    /// the max dense length, and `pos_log` is untouched (RoPE positions
    /// are logical; moving K/V between physical slots never changes them).
    pub fn apply_compact(&mut self, plan: &CompactPlan) {
        assert_eq!(plan.idx.len(), self.batch * self.cache_len);
        assert!(plan.new_frontier <= self.pos_phys, "compaction cannot grow the frontier");
        for slot in 0..self.batch {
            let row = slot * self.cache_len;
            let dense = self.valid_count(slot);
            self.valid[row..row + dense].fill(1);
            self.valid[row + dense..row + self.cache_len].fill(0);
        }
        self.pos_phys = plan.new_frontier;
        // paged: the repack moved every slot's attendable prefix below the
        // new frontier, so the tail blocks return to the pool
        if let Some(p) = self.pages.as_mut() {
            let mut pool = p.pool.borrow_mut();
            for slot in 0..p.tables.len() {
                if !p.dead[slot] {
                    p.tables[slot].truncate(&mut pool, plan.new_frontier);
                }
            }
        }
    }

    /// Permute host bookkeeping to match a device `gather(idx)`:
    /// `new[slot] = old[idx[slot]]`. Gathers through reusable scratch
    /// buffers (no per-call `valid` clone — this runs on every beam prune
    /// at `batch * cache_len` cost).
    pub fn permute_bookkeeping(&mut self, idx: &[i32]) {
        self.permute_host(idx);
        // paged: the permute is a table edit — fork the source tables
        // along idx (refcount bumps) and release the old generation
        if let Some(p) = self.pages.as_mut() {
            let mut pool = p.pool.borrow_mut();
            let mut tables = Vec::with_capacity(idx.len());
            let mut dead = Vec::with_capacity(idx.len());
            for &src in idx {
                let src = src as usize;
                tables.push(p.tables[src].fork(&mut pool));
                dead.push(p.dead[src]);
            }
            for t in &mut p.tables {
                t.release_all(&mut pool);
            }
            p.tables = tables;
            p.dead = dead;
        }
    }

    /// The dense half of [`KvSet::permute_bookkeeping`]: gather `pos_log`
    /// and `valid` along `idx` through the reusable scratch, leaving any
    /// block tables alone. The block-native gather path calls this
    /// directly — its tables are freshly allocated *copies*
    /// ([`KvSet::gather_fresh_tables`]), not forks, so the fork branch
    /// above must not run over them.
    pub fn permute_host(&mut self, idx: &[i32]) {
        assert_eq!(idx.len(), self.batch);
        let s = self.cache_len;
        self.scratch_log.clear();
        self.scratch_valid.clear();
        self.scratch_valid.reserve(self.valid.len());
        for &src in idx {
            let src = src as usize;
            assert!(src < self.batch, "gather index {src} out of range");
            self.scratch_log.push(self.pos_log[src]);
            self.scratch_valid.extend_from_slice(&self.valid[src * s..(src + 1) * s]);
        }
        std::mem::swap(&mut self.pos_log, &mut self.scratch_log);
        std::mem::swap(&mut self.valid, &mut self.scratch_valid);
    }

    /// Host bookkeeping for a device `merge(idx)` of two caches: dest slot
    /// `d` copies from `a[idx[d]]` when `idx[d] < a.batch`, else from
    /// `b[idx[d] - a.batch]` — the same union indexing the
    /// `merge_bA_bB_to_bC` programs apply to the device arrays. The merged
    /// frontier is the max of the two (lockstep discipline: future writes
    /// land at a common physical position; the gap below the laggard's own
    /// frontier stays junk, which its validity rows already encode).
    pub fn merge_bookkeeping(a: &KvSet, b: &KvSet, idx: &[i32]) -> (usize, Vec<i32>, Vec<i32>) {
        assert_eq!(a.cache_len, b.cache_len, "merging caches of different models");
        let s = a.cache_len;
        let mut pos_log = Vec::with_capacity(idx.len());
        let mut valid = Vec::with_capacity(idx.len() * s);
        for &i in idx {
            let i = i as usize;
            let (src, row) = if i < a.batch {
                (a, i)
            } else {
                assert!(i - a.batch < b.batch, "merge index {i} out of union range");
                (b, i - a.batch)
            };
            pos_log.push(src.pos_log[row]);
            valid.extend_from_slice(&src.valid[row * s..(row + 1) * s]);
        }
        (a.pos_phys.max(b.pos_phys), pos_log, valid)
    }

    /// Resize bookkeeping after broadcast b=1 -> n (device side handled by
    /// the broadcast program).
    pub fn broadcast_bookkeeping(&self, n: usize) -> (Vec<i32>, Vec<i32>) {
        assert_eq!(self.batch, 1);
        let mut pos_log = Vec::with_capacity(n);
        let mut valid = Vec::with_capacity(n * self.cache_len);
        for _ in 0..n {
            pos_log.push(self.pos_log[0]);
            valid.extend_from_slice(&self.valid[..self.cache_len]);
        }
        (pos_log, valid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(batch: usize, cache_len: usize) -> KvSet {
        KvSet::new(Vec::new(), batch, cache_len)
    }

    #[test]
    fn commit_marks_valid_and_advances_logical() {
        let mut kv = toy(2, 8);
        kv.commit(0, 0, 3);
        kv.commit(1, 0, 2);
        assert_eq!(kv.pos_log, vec![3, 2]);
        assert_eq!(&kv.valid[0..4], &[1, 1, 1, 0]);
        assert_eq!(&kv.valid[8..12], &[1, 1, 0, 0]);
    }

    #[test]
    fn frontier_advances_lockstep() {
        let mut kv = toy(2, 8);
        kv.advance_frontier(4);
        assert_eq!(kv.pos_phys, 4);
        assert_eq!(kv.remaining(), 4);
    }

    #[test]
    #[should_panic(expected = "cache overflow")]
    fn commit_overflow_panics() {
        let mut kv = toy(1, 4);
        kv.commit(0, 2, 3);
    }

    #[test]
    fn permute_bookkeeping_matches_gather_semantics() {
        let mut kv = toy(3, 4);
        kv.commit(0, 0, 1);
        kv.commit(1, 0, 2);
        kv.commit(2, 0, 3);
        kv.permute_bookkeeping(&[2, 2, 0]);
        assert_eq!(kv.pos_log, vec![3, 3, 1]);
        assert_eq!(&kv.valid[0..4], &[1, 1, 1, 0]); // slot0 = old slot2
        assert_eq!(&kv.valid[8..12], &[1, 0, 0, 0]); // slot2 = old slot0
    }

    #[test]
    fn merge_bookkeeping_unions_two_caches() {
        let mut a = toy(2, 4);
        a.commit(0, 0, 1);
        a.commit(1, 0, 2);
        a.pos_phys = 2;
        let mut b = toy(2, 4);
        b.commit(0, 0, 3);
        b.pos_phys = 3;
        // dest = [a0, a1, b0, b1], padding slot replays a0
        let (pos, log, valid) = KvSet::merge_bookkeeping(&a, &b, &[0, 1, 2, 3, 0]);
        assert_eq!(pos, 3, "merged frontier is the max of the two");
        assert_eq!(log, vec![1, 2, 3, 0, 1]);
        assert_eq!(&valid[0..4], &[1, 0, 0, 0]); // a0
        assert_eq!(&valid[4..8], &[1, 1, 0, 0]); // a1
        assert_eq!(&valid[8..12], &[1, 1, 1, 0]); // b0
        assert_eq!(&valid[12..16], &[0, 0, 0, 0]); // b1 (uncommitted)
        assert_eq!(&valid[16..20], &[1, 0, 0, 0]); // padding replays a0
    }

    #[test]
    #[should_panic(expected = "out of union range")]
    fn merge_bookkeeping_rejects_out_of_range() {
        let a = toy(2, 4);
        let b = toy(2, 4);
        let _ = KvSet::merge_bookkeeping(&a, &b, &[4]);
    }

    /// The gang-batching correctness core, as a property over the host
    /// model: merging two caches and then gathering a slot out of the
    /// union must read exactly the bookkeeping a per-cache gather of the
    /// source slot would have read.
    #[test]
    fn prop_merge_then_gather_equals_per_cache_gather() {
        use crate::util::propcheck::check_simple;
        check_simple(
            "merge-then-gather",
            |rng| {
                let s = 4 + rng.below(4); // cache_len
                let ba = 1 + rng.below(4);
                let bb = 1 + rng.below(4);
                let mk = |rng: &mut crate::util::rng::Rng, batch: usize| {
                    let mut kv = KvSet::new(Vec::new(), batch, s);
                    kv.pos_phys = rng.below(s);
                    for slot in 0..batch {
                        let n = rng.below(s + 1);
                        if n > 0 {
                            kv.commit(slot, 0, n);
                        }
                    }
                    (kv.pos_phys, kv.pos_log, kv.valid)
                };
                let a = mk(rng, ba);
                let b = mk(rng, bb);
                let pick = rng.below(ba + bb);
                (s, ba, bb, a, b, pick)
            },
            |&(s, ba, bb, ref a, ref b, pick)| {
                let rebuild = |batch: usize, st: &(usize, Vec<i32>, Vec<i32>)| {
                    let mut kv = KvSet::new(Vec::new(), batch, s);
                    kv.pos_phys = st.0;
                    kv.pos_log = st.1.clone();
                    kv.valid = st.2.clone();
                    kv
                };
                let ka = rebuild(ba, a);
                let kb = rebuild(bb, b);
                // merge the full union, then gather `pick`
                let idx: Vec<i32> = (0..(ba + bb) as i32).collect();
                let (pos, log, valid) = KvSet::merge_bookkeeping(&ka, &kb, &idx);
                let mut merged = KvSet::new(Vec::new(), ba + bb, s);
                merged.pos_phys = pos;
                merged.pos_log = log;
                merged.valid = valid;
                merged.permute_bookkeeping(&vec![pick as i32; ba + bb]);
                // reference: gather straight out of the source cache
                let (src, row) = if pick < ba { (&ka, pick) } else { (&kb, pick - ba) };
                if merged.pos_log[0] != src.pos_log[row] {
                    return Err(format!(
                        "pos_log {} != source {}",
                        merged.pos_log[0], src.pos_log[row]
                    ));
                }
                if merged.valid[0..s] != src.valid[row * s..(row + 1) * s] {
                    return Err("valid row diverged from per-cache gather".into());
                }
                if merged.pos_phys < src.pos_phys {
                    return Err("merged frontier went backwards".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn junk_fraction_and_reclaimable_track_the_gap() {
        let mut kv = toy(2, 8);
        assert_eq!(kv.junk_fraction(), 0.0, "fresh cache has no spent positions");
        assert_eq!(kv.reclaimable(), 0);
        // frontier at 6; slot0 holds 4 clean tokens, slot1 holds 2
        kv.commit(0, 0, 2);
        kv.commit(0, 3, 2);
        kv.commit(1, 1, 2);
        kv.pos_phys = 6;
        assert_eq!(kv.valid_count(0), 4);
        assert_eq!(kv.valid_count(1), 2);
        assert!((kv.junk_fraction() - 0.5).abs() < 1e-12, "6 junk of 12 spent");
        assert_eq!(kv.reclaimable(), 2, "frontier 6 drops to max dense 4");
    }

    #[test]
    fn compact_plan_packs_valid_positions_in_order() {
        let mut kv = toy(2, 8);
        kv.commit(0, 0, 2); // slot0 valid at {0,1,4}
        kv.commit(0, 4, 1);
        kv.commit(1, 3, 1); // slot1 valid at {3}
        kv.pos_phys = 6;
        let plan = kv.compact_plan().expect("junk to reclaim");
        assert_eq!(plan.new_frontier, 3);
        assert_eq!(plan.reclaimed, 3);
        assert_eq!(&plan.idx[0..3], &[0, 1, 4], "slot0 sources, original order");
        assert_eq!(plan.idx[8], 3, "slot1 source");
        kv.apply_compact(&plan);
        assert_eq!(kv.pos_phys, 3);
        assert_eq!(&kv.valid[0..4], &[1, 1, 1, 0], "slot0 packed dense");
        assert_eq!(&kv.valid[8..12], &[1, 0, 0, 0], "slot1 packed dense");
        assert_eq!(kv.pos_log, vec![3, 1], "logical positions untouched");
        assert_eq!(kv.remaining(), 5, "capacity reclaimed");
        assert!(kv.compact_plan().is_none(), "a packed cache has nothing left to reclaim");
    }

    #[test]
    fn compact_plan_none_when_dense() {
        let mut kv = toy(2, 8);
        kv.commit(0, 0, 3);
        kv.pos_phys = 3; // slot0 dense up to the frontier
        assert!(kv.compact_plan().is_none());
    }

    /// The re-compaction correctness core, over a host model of the device
    /// arrays: gathering a cache through `CompactPlan::idx` and then
    /// reading each slot's valid positions must yield exactly the token
    /// sequence the uncompacted cache's valid positions held (same values,
    /// same order), with the frontier lowered to the max dense length —
    /// i.e. compact-then-read is indistinguishable from never having
    /// fragmented.
    #[test]
    fn prop_compact_preserves_attendable_sequence() {
        use crate::util::propcheck::check_simple;
        check_simple(
            "compact-preserves-attendable",
            |rng| {
                let s = 4 + rng.below(8);
                let batch = 1 + rng.below(4);
                let mut kv = KvSet::new(Vec::new(), batch, s);
                kv.pos_phys = rng.below(s + 1);
                // random valid bits strictly below the frontier (the
                // lockstep discipline: commits never pass pos_phys)
                for slot in 0..batch {
                    for p in 0..kv.pos_phys {
                        if rng.below(2) == 1 {
                            kv.valid[slot * s + p] = 1;
                        }
                    }
                    kv.pos_log[slot] = kv.valid_count(slot) as i32;
                }
                // host model of one device plane: cell = encoded position
                let cells: Vec<i32> =
                    (0..batch * s).map(|i| (i % s) as i32 + 1000 * (i / s) as i32).collect();
                (s, batch, kv.pos_phys, kv.pos_log.clone(), kv.valid.clone(), cells)
            },
            |&(s, batch, pos_phys, ref pos_log, ref valid, ref cells)| {
                let mut kv = KvSet::new(Vec::new(), batch, s);
                kv.pos_phys = pos_phys;
                kv.pos_log = pos_log.clone();
                kv.valid = valid.clone();
                let before: Vec<Vec<i32>> = (0..batch)
                    .map(|slot| {
                        (0..s)
                            .filter(|&p| kv.valid[slot * s + p] != 0)
                            .map(|p| cells[slot * s + p])
                            .collect()
                    })
                    .collect();
                let Some(plan) = kv.compact_plan() else {
                    // nothing reclaimed: every slot's dense length must
                    // already reach the frontier
                    let max_dense = (0..batch).map(|sl| kv.valid_count(sl)).max().unwrap_or(0);
                    return if max_dense == pos_phys {
                        Ok(())
                    } else {
                        Err("no plan despite a junk gap".into())
                    };
                };
                // device-gather model: out[slot][p] = cells[slot][idx[slot][p]]
                let gathered: Vec<i32> = (0..batch * s)
                    .map(|i| cells[(i / s) * s + plan.idx[i] as usize])
                    .collect();
                kv.apply_compact(&plan);
                if kv.pos_phys != plan.new_frontier {
                    return Err("frontier not lowered to max dense length".into());
                }
                for slot in 0..batch {
                    let after: Vec<i32> = (0..s)
                        .filter(|&p| kv.valid[slot * s + p] != 0)
                        .map(|p| gathered[slot * s + p])
                        .collect();
                    if after != before[slot] {
                        return Err(format!(
                            "slot {slot}: attendable sequence changed {:?} -> {:?}",
                            before[slot], after
                        ));
                    }
                    // packed rows must be dense prefixes ending below the
                    // new frontier
                    let dense = kv.valid_count(slot);
                    if kv.valid[slot * s..slot * s + dense].iter().any(|&v| v == 0) {
                        return Err(format!("slot {slot}: validity row not dense"));
                    }
                    if dense > kv.pos_phys {
                        return Err(format!("slot {slot}: dense length passes the frontier"));
                    }
                    if kv.pos_log[slot] != pos_log[slot] {
                        return Err("pos_log changed under compaction".into());
                    }
                }
                Ok(())
            },
        );
    }

    /// Compaction then a further gather must agree with gathering first
    /// and compacting after — the ordering-freedom the coordinator relies
    /// on when gang members compact right before a merge.
    #[test]
    fn prop_compact_commutes_with_gather_on_valid_tokens() {
        use crate::util::propcheck::check_simple;
        check_simple(
            "compact-gather-commute",
            |rng| {
                let s = 4 + rng.below(6);
                let batch = 2 + rng.below(3);
                let mut kv = KvSet::new(Vec::new(), batch, s);
                kv.pos_phys = rng.below(s + 1);
                for slot in 0..batch {
                    for p in 0..kv.pos_phys {
                        if rng.below(2) == 1 {
                            kv.valid[slot * s + p] = 1;
                        }
                    }
                }
                let perm: Vec<i32> = (0..batch).map(|_| rng.below(batch) as i32).collect();
                (s, batch, kv.pos_phys, kv.valid.clone(), perm)
            },
            |&(s, batch, pos_phys, ref valid, ref perm)| {
                let rebuild = |valid: &[i32]| {
                    let mut kv = KvSet::new(Vec::new(), batch, s);
                    kv.pos_phys = pos_phys;
                    kv.valid = valid.to_vec();
                    kv
                };
                let attendable = |kv: &KvSet, slot: usize| -> usize { kv.valid_count(slot) };
                // path A: gather, then compact
                let mut a = rebuild(valid);
                a.permute_bookkeeping(perm);
                if let Some(p) = a.compact_plan() {
                    a.apply_compact(&p);
                }
                // path B: compact, then gather
                let mut b = rebuild(valid);
                if let Some(p) = b.compact_plan() {
                    b.apply_compact(&p);
                }
                b.permute_bookkeeping(perm);
                for slot in 0..batch {
                    if attendable(&a, slot) != attendable(&b, slot) {
                        return Err(format!(
                            "slot {slot}: attendable count diverged ({} vs {})",
                            attendable(&a, slot),
                            attendable(&b, slot)
                        ));
                    }
                }
                // path A may pack tighter (post-gather junk rows gone), but
                // never looser than B's frontier
                if a.pos_phys > b.pos_phys {
                    return Err(format!(
                        "gather-then-compact frontier {} above compact-then-gather {}",
                        a.pos_phys, b.pos_phys
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn permute_reuses_scratch_without_reallocating() {
        let mut kv = toy(4, 16);
        kv.commit(0, 0, 3);
        kv.permute_bookkeeping(&[3, 2, 1, 0]);
        let cap_v = kv.scratch_valid.capacity();
        let cap_l = kv.scratch_log.capacity();
        assert!(cap_v >= 4 * 16, "scratch holds a full bitmask after one call");
        for _ in 0..4 {
            kv.permute_bookkeeping(&[0, 1, 2, 3]);
        }
        assert_eq!(kv.scratch_valid.capacity(), cap_v, "steady state allocates nothing");
        assert_eq!(kv.scratch_log.capacity(), cap_l);
    }

    #[test]
    fn broadcast_replicates_slot0() {
        let mut kv = toy(1, 4);
        kv.commit(0, 0, 2);
        let (log, valid) = kv.broadcast_bookkeeping(3);
        assert_eq!(log, vec![2, 2, 2]);
        assert_eq!(valid.len(), 12);
        assert_eq!(&valid[4..8], &[1, 1, 0, 0]);
    }

    // ------------------------------------------------------ paged caches

    use crate::runtime::blocks::shared_pool;

    fn paged_toy(batch: usize, cache_len: usize, pool: &crate::runtime::blocks::SharedPool) -> KvSet {
        let mut kv = toy(batch, cache_len);
        kv.attach_pages(pool.clone()).expect("pool covers a fresh cache");
        kv
    }

    #[test]
    fn reserve_frontier_grows_tables_lockstep() {
        let pool = shared_pool(16, 4);
        let mut kv = paged_toy(2, 16, &pool);
        assert_eq!(pool.borrow().allocated(), 0, "fresh cache holds nothing");
        kv.reserve_frontier(6).unwrap();
        kv.advance_frontier(6);
        assert_eq!(pool.borrow().allocated(), 4, "2 slots x 2 blocks");
        let p = kv.pages.as_ref().unwrap();
        assert_eq!(p.table(0).len_tokens(), 6);
        assert_eq!(p.table(0).translate(5, 4).unwrap().1, 1);
    }

    #[test]
    fn free_slot_returns_blocks_same_tick_and_junks_the_row() {
        let pool = shared_pool(16, 4);
        let mut kv = paged_toy(2, 16, &pool);
        kv.reserve_frontier(8).unwrap();
        kv.advance_frontier(8);
        kv.commit(0, 0, 8);
        kv.commit(1, 0, 8);
        assert_eq!(pool.borrow().allocated(), 4);
        kv.free_slot(1);
        // the rejected slot's blocks are free *now*, not after a compaction
        assert_eq!(pool.borrow().allocated(), 2);
        assert_eq!(pool.borrow().free_blocks(), 14);
        assert_eq!(kv.valid_count(1), 0, "freed slot attends nothing");
        assert_eq!(kv.valid_count(0), 8, "survivor untouched");
        // freed slots reserve nothing at future frontier advances
        kv.reserve_frontier(4).unwrap();
        assert_eq!(pool.borrow().allocated(), 3, "only the live slot grew");
        kv.free_slot(1); // idempotent
        assert_eq!(pool.borrow().allocated(), 3);
    }

    #[test]
    fn reserve_frontier_exhaustion_is_clean_backpressure() {
        let pool = shared_pool(3, 4);
        let mut kv = paged_toy(2, 32, &pool);
        kv.reserve_frontier(4).unwrap();
        kv.advance_frontier(4);
        assert_eq!(pool.borrow().allocated(), 2);
        // next block needs 2 more blocks; only 1 is free
        let err = kv.reserve_frontier(4).unwrap_err();
        assert_eq!(err.free_blocks, 1);
        assert_eq!(pool.borrow().allocated(), 2, "failed reserve rolled back");
        assert_eq!(kv.pos_phys, 4, "frontier untouched — caller backs off");
        // freeing a slot makes the same reservation succeed (reject → reuse)
        kv.free_slot(1);
        kv.reserve_frontier(4).unwrap();
        assert_eq!(kv.pages.as_ref().unwrap().table(0).len_tokens(), 8);
    }

    #[test]
    fn permute_forks_tables_without_new_blocks() {
        let pool = shared_pool(16, 4);
        let mut kv = paged_toy(3, 16, &pool);
        kv.reserve_frontier(4).unwrap();
        kv.advance_frontier(4);
        kv.commit(0, 0, 1);
        kv.commit(1, 0, 2);
        kv.commit(2, 0, 3);
        let before = pool.borrow().allocated();
        kv.permute_bookkeeping(&[2, 2, 0]);
        assert_eq!(kv.pos_log, vec![3, 3, 1], "dense bookkeeping unchanged");
        assert_eq!(
            pool.borrow().allocated(),
            before,
            "permute is refcount edits, not allocation"
        );
        let p = kv.pages.as_ref().unwrap();
        assert_eq!(p.table(0).blocks(), p.table(1).blocks(), "duplicated slot shares blocks");
        let b = p.table(0).blocks()[0];
        assert_eq!(pool.borrow().refcount(b), 2, "copy-on-write share");
    }

    #[test]
    fn compact_truncates_tables_to_new_frontier() {
        let pool = shared_pool(16, 2);
        let mut kv = paged_toy(2, 16, &pool);
        kv.reserve_frontier(6).unwrap();
        kv.advance_frontier(6);
        kv.commit(0, 0, 2);
        kv.commit(1, 3, 1);
        assert_eq!(pool.borrow().allocated(), 6, "2 slots x 3 blocks of 2");
        let plan = kv.compact_plan().expect("junk to reclaim");
        assert_eq!(plan.new_frontier, 2);
        kv.apply_compact(&plan);
        assert_eq!(pool.borrow().allocated(), 2, "tail blocks released by the table edit");
        assert_eq!(kv.pages.as_ref().unwrap().table(0).len_tokens(), 2);
    }

    #[test]
    fn dropping_a_paged_cache_releases_every_block() {
        let pool = shared_pool(8, 4);
        {
            let mut kv = paged_toy(2, 16, &pool);
            kv.reserve_frontier(8).unwrap();
            kv.advance_frontier(8);
            assert_eq!(pool.borrow().allocated(), 4);
        }
        assert_eq!(pool.borrow().free_blocks(), 8, "drop returned everything");
    }

    #[test]
    fn broadcast_and_merge_pages_share_by_refcount() {
        let pool = shared_pool(32, 4);
        let mut one = paged_toy(1, 16, &pool);
        one.reserve_frontier(4).unwrap();
        one.advance_frontier(4);
        one.commit(0, 0, 4);
        let held = pool.borrow().allocated();
        let bcast = one.broadcast_pages(3).expect("paged source");
        assert_eq!(pool.borrow().allocated(), held, "broadcast allocates nothing");
        assert_eq!(bcast.table(2).blocks(), one.pages.as_ref().unwrap().table(0).blocks());
        // merge = table concatenation along the union index
        let mut b = paged_toy(2, 16, &pool);
        b.reserve_frontier(8).unwrap();
        b.advance_frontier(8);
        let merged = KvSet::merge_pages(&one, &b, &[0, 1, 2, 0]).expect("both paged");
        assert_eq!(merged.table(0).blocks(), one.pages.as_ref().unwrap().table(0).blocks());
        assert_eq!(merged.table(1).blocks(), b.pages.as_ref().unwrap().table(0).blocks());
        assert_eq!(merged.table(3).blocks(), one.pages.as_ref().unwrap().table(0).blocks());
        drop(merged);
        drop(bcast);
        drop(one);
        drop(b);
        assert_eq!(pool.borrow().free_blocks(), 32, "no leak through share edits");
    }

    // ---------------------------------------------- block-native caches

    fn native_toy(batch: usize, cache_len: usize, pool: &crate::runtime::blocks::SharedPool) -> KvSet {
        let mut kv = toy(batch, cache_len);
        kv.attach_native_tables(pool.clone()).expect("pool covers a fresh cache");
        kv
    }

    #[test]
    fn native_reserve_grows_each_slot_from_its_own_frontier() {
        let pool = shared_pool(32, 4);
        let mut a = native_toy(1, 32, &pool);
        a.reserve_frontier(8).unwrap();
        a.advance_frontier(8);
        a.commit(0, 0, 8);
        let mut b = native_toy(1, 32, &pool);
        b.reserve_frontier(4).unwrap();
        b.advance_frontier(4);
        b.commit(0, 0, 4);
        let merged = KvSet::merge_tables(&a, &b, &[0, 1]).expect("both native");
        assert_eq!(merged.slot_frontiers(), vec![8, 4], "members keep their own clocks");
        let mut merged = merged;
        merged.reserve_frontier(4).unwrap();
        merged.advance_frontier(4);
        assert_eq!(merged.slot_frontiers(), vec![12, 8], "per-slot growth, no union gap");
        assert_eq!(merged.pos_phys, 12);
    }

    #[test]
    fn merge_tables_pads_are_dead_and_own_nothing() {
        let pool = shared_pool(32, 4);
        let mut a = native_toy(2, 16, &pool);
        a.reserve_frontier(4).unwrap();
        a.advance_frontier(4);
        a.commit(0, 0, 4);
        a.commit(1, 0, 4);
        let mut b = native_toy(1, 16, &pool);
        b.reserve_frontier(4).unwrap();
        b.advance_frontier(4);
        b.commit(0, 0, 4);
        let held = pool.borrow().allocated();
        // variant 4 packs [a0, a1, b0] + one pad replaying index 0
        let merged = KvSet::merge_tables(&a, &b, &[0, 1, 2, 0]).expect("both native");
        assert_eq!(pool.borrow().allocated(), held, "merge is refcount edits only");
        let p = merged.pages.as_ref().unwrap();
        assert!(p.is_dead(3), "pad slot is dead");
        assert!(p.table(3).is_empty(), "pad forks nothing — no frontier-block collision");
        assert_eq!(merged.pos_log[3], 0);
        assert_eq!(merged.valid_count(3), 0);
        assert_eq!(p.table(0).blocks(), a.pages.as_ref().unwrap().table(0).blocks());
        assert_eq!(p.table(2).blocks(), b.pages.as_ref().unwrap().table(0).blocks());
        drop(merged);
        drop(a);
        drop(b);
        assert_eq!(pool.borrow().free_blocks(), 32, "no leak through the merge");
    }

    #[test]
    fn split_tables_restores_member_frontier_and_bookkeeping() {
        let pool = shared_pool(64, 4);
        let mut a = native_toy(2, 32, &pool);
        a.reserve_frontier(8).unwrap();
        a.advance_frontier(8);
        a.commit(0, 0, 8);
        a.commit(1, 0, 6);
        let mut b = native_toy(1, 32, &pool);
        b.reserve_frontier(4).unwrap();
        b.advance_frontier(4);
        b.commit(0, 0, 4);
        let mut merged = KvSet::merge_tables(&a, &b, &[0, 1, 2, 0]).expect("both native");
        // one shared block write of 4: every live slot advances by 4
        merged.reserve_frontier(4).unwrap();
        merged.advance_frontier(4);
        let ma = merged.split_tables(0, 2).expect("native split");
        let mb = merged.split_tables(2, 1).expect("native split");
        assert_eq!(ma.pos_phys, 12, "member a frontier = own 8 + 4, not union max");
        assert_eq!(mb.pos_phys, 8, "member b frontier = own 4 + 4");
        assert_eq!(ma.pos_log, a.pos_log);
        assert_eq!(mb.pos_log, b.pos_log);
        assert_eq!(ma.valid, a.valid);
        assert_eq!(mb.valid, b.valid);
        drop(merged);
        drop(ma);
        drop(mb);
        drop(a);
        drop(b);
        assert_eq!(pool.borrow().free_blocks(), 64, "split/merge conserve the pool");
    }

    #[test]
    fn compact_tables_truncates_uniformly_and_keeps_rows_in_place() {
        let pool = shared_pool(32, 2);
        let mut kv = native_toy(2, 16, &pool);
        kv.reserve_frontier(10).unwrap();
        kv.advance_frontier(10);
        kv.commit(0, 0, 2); // slot0 tail ends at 2
        kv.commit(1, 3, 3); // slot1 tail ends at 6 — the common target
        let valid_before = kv.valid.clone();
        assert_eq!(kv.reclaimable(), 4, "tail reclaim, not the repack number");
        let (reclaimed, freed) = kv.compact_tables();
        assert_eq!(reclaimed, 4);
        assert!(freed > 0, "tail blocks went back to the pool");
        assert_eq!(kv.pos_phys, 6);
        assert_eq!(kv.valid, valid_before, "no repack: rows stay in place");
        let p = kv.pages.as_ref().unwrap();
        assert_eq!(p.table(0).len_tokens(), 6, "uniform truncation keeps slots lockstep");
        assert_eq!(p.table(1).len_tokens(), 6);
        assert_eq!(kv.compact_tables(), (0, 0), "nothing left to truncate");
    }

    #[test]
    fn native_reclaimable_counts_only_the_common_tail() {
        let pool = shared_pool(32, 4);
        let mut kv = native_toy(2, 16, &pool);
        kv.reserve_frontier(8).unwrap();
        kv.advance_frontier(8);
        kv.commit(0, 0, 2);
        kv.commit(0, 6, 2); // interior hole at {2..6}, tail reaches 8
        kv.commit(1, 0, 2);
        assert_eq!(kv.reclaimable(), 0, "tail occupied: truncation reclaims nothing");
        let dense_twin = {
            let mut d = toy(2, 16);
            d.pos_phys = kv.pos_phys;
            d.valid = kv.valid.clone();
            d
        };
        assert_eq!(dense_twin.reclaimable(), 4, "the repack would reclaim the holes");
    }

    #[test]
    fn gather_fresh_tables_copies_instead_of_sharing() {
        let pool = shared_pool(32, 4);
        let mut kv = native_toy(2, 16, &pool);
        kv.reserve_frontier(8).unwrap();
        kv.advance_frontier(8);
        let held = pool.borrow().allocated();
        let fresh = kv.gather_fresh_tables(&[0, 0]).expect("pool has room");
        assert_eq!(pool.borrow().allocated(), held + 4, "two fresh 2-block tables");
        let orig = kv.pages.as_ref().unwrap();
        assert_ne!(fresh.table(0).blocks(), orig.table(0).blocks(), "no sharing");
        assert_ne!(fresh.table(0).blocks(), fresh.table(1).blocks(), "children independent");
        for &b in fresh.table(0).blocks() {
            assert_eq!(pool.borrow().refcount(b), 1, "fresh blocks are unshared");
        }
        drop(fresh);
        assert_eq!(pool.borrow().allocated(), held, "fresh generation released cleanly");
    }

    /// Observational identity of the table-edit gang path: merging two
    /// random members with [`KvSet::merge_tables`] and splitting them back
    /// out must reproduce each member's bookkeeping exactly (the device
    /// rows never moved, so bookkeeping identity *is* observational
    /// identity), with pad slots dead, per-slot frontiers preserved
    /// through a shared block write, and the pool refcount-balanced after
    /// every cache drops.
    #[test]
    fn prop_merge_split_tables_round_trips_members() {
        use crate::util::propcheck::check_simple;
        check_simple(
            "merge-split-tables-round-trip",
            |rng| {
                let s = 16 + 4 * rng.below(4);
                let ba = 1 + rng.below(3);
                let bb = 1 + rng.below(3);
                let fa = 4 * (1 + rng.below(2)); // member frontiers (block multiples)
                let fb = 4 * (1 + rng.below(2));
                let commits_a: Vec<usize> = (0..ba).map(|_| rng.below(fa + 1)).collect();
                let commits_b: Vec<usize> = (0..bb).map(|_| rng.below(fb + 1)).collect();
                let pad = rng.below(3); // extra pad slots in the variant
                (s, ba, bb, fa, fb, commits_a, commits_b, pad)
            },
            |&(s, ba, bb, fa, fb, ref commits_a, ref commits_b, pad)| {
                let pool = shared_pool(4 * (ba + bb) * (s / 4), 4);
                let build = |batch: usize, f: usize, commits: &[usize]| {
                    let mut kv = KvSet::new(Vec::new(), batch, s);
                    kv.attach_native_tables(pool.clone()).expect("sized for the run");
                    kv.reserve_frontier(f).map_err(|e| e.to_string())?;
                    kv.advance_frontier(f);
                    for (slot, &n) in commits.iter().enumerate() {
                        if n > 0 {
                            kv.commit(slot, 0, n);
                        }
                    }
                    Ok::<KvSet, String>(kv)
                };
                let a = build(ba, fa, commits_a)?;
                let b = build(bb, fb, commits_b)?;
                let mut idx: Vec<i32> = (0..(ba + bb) as i32).collect();
                idx.extend(std::iter::repeat(0).take(pad));
                let mut merged =
                    KvSet::merge_tables(&a, &b, &idx).ok_or("members are block-native")?;
                if merged.pos_phys != fa.max(fb) {
                    return Err("merged frontier is not the member max".into());
                }
                // one shared block write: every live slot advances by 4
                merged.reserve_frontier(4).map_err(|e| e.to_string())?;
                merged.advance_frontier(4);
                let sa = merged.split_tables(0, ba).ok_or("native split")?;
                let sb = merged.split_tables(ba, bb).ok_or("native split")?;
                for (m, src, f) in [(&sa, &a, fa), (&sb, &b, fb)] {
                    if m.pos_phys != f + 4 {
                        return Err(format!(
                            "member frontier {} != own clock {}",
                            m.pos_phys,
                            f + 4
                        ));
                    }
                    if m.pos_log != src.pos_log || m.valid != src.valid {
                        return Err("member bookkeeping changed through merge+split".into());
                    }
                    let mp = m.pages.as_ref().expect("split is paged");
                    for slot in 0..m.batch {
                        if !mp.is_dead(slot) && mp.table(slot).len_tokens() != m.pos_phys {
                            return Err(format!("slot {slot} table off the member frontier"));
                        }
                    }
                }
                for d in (ba + bb)..idx.len() {
                    let mp = merged.pages.as_ref().expect("paged");
                    if !mp.is_dead(d) || !mp.table(d).is_empty() {
                        return Err("pad slot owns blocks".into());
                    }
                }
                drop(merged);
                drop(sa);
                drop(sb);
                drop(a);
                drop(b);
                let pl = pool.borrow();
                if pl.free_blocks() != pl.total() {
                    return Err("blocks leaked through merge/split".into());
                }
                Ok(())
            },
        );
    }

    /// Observational identity of the table-edit compaction: on a cache
    /// whose junk is all *tail* (the shape gang pacing produces),
    /// `compact_tables` must reclaim exactly what the device-gather repack
    /// would, leave every attendable (position -> value) pair untouched
    /// (nothing moves, so this is immediate — the property pins it), and
    /// keep live tables covering the frontier with the pool conserved.
    #[test]
    fn prop_compact_tables_matches_repack_on_tail_junk() {
        use crate::util::propcheck::check_simple;
        check_simple(
            "compact-tables-vs-repack",
            |rng| {
                let s = 16 + 4 * rng.below(4);
                let batch = 1 + rng.below(4);
                let f = 4 * (1 + rng.below(s / 4));
                // dense prefixes only — tail-junk shape, where truncation
                // and repack agree on the reclaim
                let dense: Vec<usize> = (0..batch).map(|_| rng.below(f + 1)).collect();
                (s, batch, f, dense)
            },
            |&(s, batch, f, ref dense)| {
                let pool = shared_pool(batch * s / 4 + batch, 4);
                let mut kv = KvSet::new(Vec::new(), batch, s);
                kv.attach_native_tables(pool.clone()).map_err(|e| e.to_string())?;
                kv.reserve_frontier(f).map_err(|e| e.to_string())?;
                kv.advance_frontier(f);
                for (slot, &n) in dense.iter().enumerate() {
                    if n > 0 {
                        kv.commit(slot, 0, n);
                    }
                }
                let mut twin = KvSet::new(Vec::new(), batch, s);
                twin.pos_phys = kv.pos_phys;
                twin.pos_log = kv.pos_log.clone();
                twin.valid = kv.valid.clone();
                let valid_before = kv.valid.clone();
                let want = twin.reclaimable();
                if kv.reclaimable() != want {
                    return Err("tail-junk reclaim estimate diverged from repack".into());
                }
                let (reclaimed, _) = kv.compact_tables();
                if reclaimed != want {
                    return Err(format!("truncation reclaimed {reclaimed}, repack {want}"));
                }
                if let Some(plan) = twin.compact_plan() {
                    twin.apply_compact(&plan);
                }
                if kv.pos_phys != twin.pos_phys {
                    return Err("frontiers diverged from the repack twin".into());
                }
                if kv.valid != valid_before {
                    return Err("truncation moved validity rows".into());
                }
                let p = kv.pages.as_ref().expect("paged");
                for slot in 0..batch {
                    if !p.is_dead(slot) && p.table(slot).len_tokens() != kv.pos_phys {
                        return Err(format!("slot {slot} table off the frontier"));
                    }
                }
                drop(kv);
                let pl = pool.borrow();
                if pl.free_blocks() != pl.total() {
                    return Err("pool conservation broken".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn table_operand_pads_with_trash_and_masks_dead_slots() {
        let pool = shared_pool(16, 4);
        let mut kv = native_toy(2, 16, &pool);
        kv.reserve_frontier(8).unwrap();
        kv.advance_frontier(8);
        kv.commit(0, 0, 8);
        kv.commit(1, 0, 8);
        kv.free_slot(1);
        let trash = 16i32; // pool row P in a P=16 pool
        let op = kv.table_operand(4, trash);
        assert_eq!(op.len(), 8);
        let p = kv.pages.as_ref().unwrap();
        let live: Vec<i32> = p.table(0).blocks().iter().map(|&b| b as i32).collect();
        assert_eq!(&op[0..2], &live[..], "live blocks verbatim");
        assert_eq!(&op[2..4], &[trash, trash], "unreserved logical blocks pad with trash");
        assert_eq!(&op[4..8], &[trash; 4], "dead slot is all trash");
        assert_eq!(kv.slot_frontiers(), vec![8, 0], "dead slot frontier masks everything");
    }

    /// Paged bookkeeping is invisible to the dense discipline: running an
    /// arbitrary commit/advance/permute/compact sequence on a paged cache
    /// and a dense twin yields byte-identical `pos_log`/`valid`/frontier,
    /// while the pool conserves blocks throughout — the host half of the
    /// paged-vs-dense byte-identity contract.
    #[test]
    fn prop_paged_bookkeeping_matches_dense_twin() {
        use crate::util::propcheck::check_simple;
        #[derive(Debug, Clone)]
        enum Op {
            Advance(usize),
            Commit(usize, usize),
            Permute(Vec<i32>),
            Free(usize),
            Compact,
        }
        check_simple(
            "paged-matches-dense",
            |rng| {
                let s = 8 + rng.below(8);
                let batch = 1 + rng.below(4);
                let ops: Vec<Op> = (0..rng.below(16))
                    .map(|_| match rng.below(5) {
                        0 => Op::Advance(1 + rng.below(4)),
                        1 => Op::Commit(rng.below(batch), 1 + rng.below(3)),
                        2 => Op::Permute((0..batch).map(|_| rng.below(batch) as i32).collect()),
                        3 => Op::Free(rng.below(batch)),
                        _ => Op::Compact,
                    })
                    .collect();
                (s, batch, ops)
            },
            |&(s, batch, ref ops)| {
                let pool = shared_pool(batch * s, 4);
                let mut paged = KvSet::new(Vec::new(), batch, s);
                paged.attach_pages(pool.clone()).map_err(|e| e.to_string())?;
                let mut dense = KvSet::new(Vec::new(), batch, s);
                let mut freed = vec![false; batch];
                for op in ops {
                    match *op {
                        Op::Advance(n) => {
                            if paged.remaining() < n {
                                continue;
                            }
                            paged.reserve_frontier(n).map_err(|e| e.to_string())?;
                            paged.advance_frontier(n);
                            dense.advance_frontier(n);
                        }
                        Op::Commit(slot, n) => {
                            // lockstep discipline: commits stay below the frontier
                            if freed[slot] || paged.pos_phys < n {
                                continue;
                            }
                            let start = paged.pos_phys - n;
                            paged.commit(slot, start, n);
                            dense.commit(slot, start, n);
                        }
                        Op::Permute(ref idx) => {
                            paged.permute_bookkeeping(idx);
                            dense.permute_bookkeeping(idx);
                            let old = freed.clone();
                            for (d, &src) in idx.iter().enumerate() {
                                freed[d] = old[src as usize];
                            }
                        }
                        Op::Free(slot) => {
                            paged.free_slot(slot);
                            // mirror the validity edit on the dense twin
                            dense.valid[slot * s..(slot + 1) * s].fill(0);
                            freed[slot] = true;
                        }
                        Op::Compact => {
                            if let Some(plan) = paged.compact_plan() {
                                paged.apply_compact(&plan);
                                let dplan = dense.compact_plan().expect("twins agree");
                                if dplan.new_frontier != plan.new_frontier {
                                    return Err("twins planned different frontiers".into());
                                }
                                dense.apply_compact(&dplan);
                            }
                        }
                    }
                    if paged.pos_phys != dense.pos_phys
                        || paged.pos_log != dense.pos_log
                        || paged.valid != dense.valid
                    {
                        return Err("paged bookkeeping diverged from the dense twin".into());
                    }
                    let pl = pool.borrow();
                    if pl.free_blocks() + pl.allocated() != pl.total() {
                        return Err("pool conservation broken".into());
                    }
                    // every live slot's table covers the frontier
                    let p = paged.pages.as_ref().expect("attached");
                    for slot in 0..batch {
                        if !p.is_dead(slot) && p.table(slot).len_tokens() < paged.pos_phys {
                            return Err(format!("slot {slot} table behind the frontier"));
                        }
                    }
                }
                drop(paged);
                if pool.borrow().free_blocks() != pool.borrow().total() {
                    return Err("blocks leaked after drop".into());
                }
                Ok(())
            },
        );
    }
}
