//! The PJRT execution engine: compiles HLO-text artifacts on demand,
//! uploads weight checkpoints once, and exposes the typed call surface the
//! coordinator drives. All state (KV caches, weights) stays device-resident
//! between calls via `execute_b_untuple` (see `third_party/xla-rs`).

use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use xla::{PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::artifacts::{Manifest, ModelArch};
use super::blocks::{shared_pool, SharedPool};
use super::kv::KvSet;
use crate::log_debug;
use crate::log_info;
use crate::util::error::{Error, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    Lm,
    Prm,
}

/// `dst[slot] = src[idx[slot]]` for logical positions and validity rows.
fn copy_bookkeeping(src: &KvSet, dst: &mut KvSet, idx: &[i32]) {
    for (d, &s) in idx.iter().enumerate() {
        let s = s as usize;
        assert!(s < src.batch, "resize index {s} out of range {}", src.batch);
        dst.pos_log[d] = src.pos_log[s];
        let (d0, s0) = (d * dst.cache_len, s * src.cache_len);
        dst.valid[d0..d0 + dst.cache_len].copy_from_slice(&src.valid[s0..s0 + src.cache_len]);
    }
}

/// Wall-clock samples for one program class at one batch width — the
/// gang planner's cost-model calibration data.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct CallWall {
    pub calls: u64,
    pub wall_s: f64,
}

impl CallWall {
    pub fn mean_s(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.wall_s / self.calls as f64
        }
    }
}

/// Aggregate runtime counters (for /metrics and perf work).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub executions: u64,
    /// `decode_bN` invocations — the gang batcher's acceptance metric:
    /// merging requests into shared batches must lower decode (and score)
    /// invocations per completed request, not just shuffle work around.
    pub decode_calls: u64,
    /// `score_bN` invocations.
    pub score_calls: u64,
    /// `merge_bA_bB_to_bC` invocations (gang assembly overhead).
    pub merge_calls: u64,
    /// `compact_bN` invocations (frontier re-compaction).
    pub compact_calls: u64,
    /// Physical cache positions reclaimed by compactions (device-program
    /// repacks and block-native table truncations both count here).
    pub compact_reclaimed: u64,
    /// Block-native gang merges done as pure block-table edits — each one
    /// replaces a `merge_bA_bB_to_bC` device call with zero device work.
    pub table_merges: u64,
    /// Block-native gang splits done as pure block-table edits (replacing
    /// `resize`/`gather` device calls).
    pub table_splits: u64,
    /// Block-native compactions done as uniform table truncations
    /// (replacing `compact_bN` device repacks).
    pub table_compacts: u64,
    /// Junk positions observed below the lockstep frontier at decode and
    /// score time, over all positions spent — `junk_positions /
    /// cache_positions` is the live cache-utilization gauge
    /// (`erprm_kv_junk_fraction` on /metrics).
    pub junk_positions: u64,
    pub cache_positions: u64,
    /// Per-batch-width wall samples of decode/score calls, and aggregate
    /// merge and gather/resize/split walls — the calibration inputs of
    /// the gang planner's wall-clock packing cost model.
    pub decode_wall: BTreeMap<usize, CallWall>,
    pub score_wall: BTreeMap<usize, CallWall>,
    pub merge_wall_s: f64,
    pub gather_calls: u64,
    pub gather_wall_s: f64,
    pub compiles: u64,
    pub compile_wall_s: f64,
    pub execute_wall_s: f64,
    pub host_bytes_up: u64,
    pub host_bytes_down: u64,
    /// Paged-KV pool gauges, snapshotted by [`Engine::stats`] (all zero
    /// when paging is off). Each shard owns its own pool, so summing in
    /// `merge` yields fleet-wide totals for `/metrics`.
    pub pool_blocks_total: u64,
    pub pool_blocks_free: u64,
    /// High-water mark of blocks in use — the acceptance gauge paged
    /// allocation is judged by (lower than the dense-equivalent footprint
    /// at equal traffic).
    pub pool_hwm: u64,
}

impl EngineStats {
    /// Accumulate another engine's counters into this one. Used by the
    /// shard pool to aggregate stats across per-shard engines for
    /// `/metrics` (wall-clock fields sum, so they read as total
    /// engine-seconds across shards, not elapsed time).
    pub fn merge(&mut self, other: &EngineStats) {
        self.executions += other.executions;
        self.decode_calls += other.decode_calls;
        self.score_calls += other.score_calls;
        self.merge_calls += other.merge_calls;
        self.compact_calls += other.compact_calls;
        self.compact_reclaimed += other.compact_reclaimed;
        self.table_merges += other.table_merges;
        self.table_splits += other.table_splits;
        self.table_compacts += other.table_compacts;
        self.junk_positions += other.junk_positions;
        self.cache_positions += other.cache_positions;
        for (&b, w) in &other.decode_wall {
            let e = self.decode_wall.entry(b).or_default();
            e.calls += w.calls;
            e.wall_s += w.wall_s;
        }
        for (&b, w) in &other.score_wall {
            let e = self.score_wall.entry(b).or_default();
            e.calls += w.calls;
            e.wall_s += w.wall_s;
        }
        self.merge_wall_s += other.merge_wall_s;
        self.gather_calls += other.gather_calls;
        self.gather_wall_s += other.gather_wall_s;
        self.compiles += other.compiles;
        self.compile_wall_s += other.compile_wall_s;
        self.execute_wall_s += other.execute_wall_s;
        self.host_bytes_up += other.host_bytes_up;
        self.host_bytes_down += other.host_bytes_down;
        self.pool_blocks_total += other.pool_blocks_total;
        self.pool_blocks_free += other.pool_blocks_free;
        self.pool_hwm += other.pool_hwm;
    }

    /// Junk share of all cache positions spent by decode/score calls so
    /// far (0.0 before any call) — effective cache utilization is its
    /// complement.
    pub fn junk_fraction(&self) -> f64 {
        if self.cache_positions == 0 {
            0.0
        } else {
            self.junk_positions as f64 / self.cache_positions as f64
        }
    }
}

pub struct Engine {
    client: PjRtClient,
    pub manifest: Manifest,
    exes: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    weights: RefCell<HashMap<String, Rc<Vec<PjRtBuffer>>>>,
    stats: RefCell<EngineStats>,
    /// The shard's shared KV block pool. `None` runs the dense
    /// fixed-length discipline; set by [`Engine::enable_paging`] when the
    /// artifact set carries a `kv_block` size.
    pool: RefCell<Option<SharedPool>>,
    /// Block-native mode: attention programs index the shared device pool
    /// through block-table operands, so gang merge/split/compact become
    /// pure host table edits. Set by [`Engine::enable_paging`] when the
    /// artifact set exports the `*_blocktab_b{b}` program family for every
    /// batch variant.
    block_native: Cell<bool>,
    /// Per-arch device-resident KV pool arrays (`[pool_blocks + 1, heads,
    /// kv_block, head_dim]` per layer K/V; last row is the trash block).
    /// Taken out of the map for each blocktab call (the buffers are
    /// donated) and replaced with the call's outputs.
    pool_dev: RefCell<HashMap<String, Vec<PjRtBuffer>>>,
}

impl Engine {
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu()?;
        log_info!(
            "engine up: platform={} devices={} models={:?}",
            client.platform_name(),
            client.device_count(),
            manifest.models.keys().collect::<Vec<_>>()
        );
        Ok(Engine {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            weights: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
            pool: RefCell::new(None),
            block_native: Cell::new(false),
            pool_dev: RefCell::new(HashMap::new()),
        })
    }

    pub fn stats(&self) -> EngineStats {
        let mut s = self.stats.borrow().clone();
        if let Some(pool) = self.pool.borrow().as_ref() {
            let ps = pool.borrow().stats();
            s.pool_blocks_total = ps.blocks_total as u64;
            s.pool_blocks_free = ps.blocks_free as u64;
            s.pool_hwm = ps.hwm as u64;
        }
        s
    }

    /// Switch this engine to paged KV allocation over a shared pool of
    /// `total_blocks` blocks (block size from the manifest's `kv_block`).
    /// Returns `false` — leaving the dense discipline untouched — when
    /// the artifact set predates paging (no `kv_block`) or `total_blocks`
    /// is 0, so older artifact dirs keep working unchanged.
    pub fn enable_paging(&self, total_blocks: usize) -> bool {
        let Some(bs) = self.manifest.kv_block else {
            return false;
        };
        if total_blocks == 0 {
            return false;
        }
        // Block-native needs the full blocktab program family for every
        // batch variant of every model — mixing table-indexed and dense
        // calls against one cache would corrupt it, so the mode is
        // all-or-nothing per engine.
        let native = self.manifest.pool_blocks.is_some()
            && self
                .manifest
                .models
                .values()
                .all(|m| m.block_native_ready(&self.manifest.batch_variants));
        let total = match (native, self.manifest.pool_blocks) {
            // device pool geometry is baked into the exported programs:
            // host block ids must stay below `pool_blocks` (the last row
            // is the trash block), so clamp the host pool to fit
            (true, Some(p)) => total_blocks.min(p),
            _ => total_blocks,
        };
        *self.pool.borrow_mut() = Some(shared_pool(total, bs));
        self.block_native.set(native);
        log_info!(
            "paged KV on: {total} blocks x {bs} tokens{}",
            if native { " (block-native attention)" } else { "" }
        );
        true
    }

    pub fn paging_enabled(&self) -> bool {
        self.pool.borrow().is_some()
    }

    /// Whether attention runs block-native (table-indexed device pool;
    /// merge/split/compact are host table edits).
    pub fn block_native(&self) -> bool {
        self.block_native.get()
    }

    /// Drop back to gather-paged execution after [`Engine::enable_paging`]
    /// selected block-native attention. The equivalence suite uses this
    /// to pin all three execution modes — dense, gather-paged,
    /// block-native — to byte-identical outcomes on one artifact set;
    /// production paths have no reason to call it.
    pub fn disable_block_native(&self) {
        self.block_native.set(false);
    }

    /// Point-in-time pool gauges (`None` when paging is off).
    pub fn pool_stats(&self) -> Option<super::blocks::PoolStats> {
        self.pool.borrow().as_ref().map(|p| p.borrow().stats())
    }

    /// Free blocks a *new* request must find before admission: one LM plus
    /// one PRM prompt cache, broadcast to the widest exported batch
    /// variant. Conservative by construction — a request clearing this
    /// floor can always prefill and broadcast without starving work
    /// already in flight. 0 when paging is off (admission then falls back
    /// to slot counting alone).
    pub fn pool_admission_floor(&self) -> usize {
        let Some(ps) = self.pool_stats() else {
            return 0;
        };
        let per_cache = self.manifest.prompt_pad.div_ceil(ps.block_size);
        let widest = self.manifest.batch_variants.iter().copied().max().unwrap_or(1);
        2 * widest * per_cache
    }

    /// Whether the pool has admission headroom for one more request
    /// (always `true` when paging is off).
    pub fn pool_has_headroom(&self) -> bool {
        match self.pool_stats() {
            None => true,
            Some(ps) => ps.blocks_free >= self.pool_admission_floor(),
        }
    }

    /// Attach block tables to a fresh cache when paging is on. Pool
    /// exhaustion at prefill time is backpressure, not corruption: the
    /// request should have been queued, so surface `Saturated` (HTTP 503
    /// + Retry-After) with the cache still dense and nothing leaked.
    fn attach_pages(&self, kv: &mut KvSet) -> Result<()> {
        if let Some(pool) = self.pool.borrow().as_ref() {
            kv.attach_pages(pool.clone()).map_err(|e| Error::saturated(e.to_string()))?;
        }
        Ok(())
    }

    /// Attach block-native tables (fresh, unshared, covering the current
    /// frontier) to a cache. Only meaningful in block-native mode.
    fn attach_native(&self, kv: &mut KvSet) -> Result<()> {
        let pool = self
            .pool
            .borrow()
            .as_ref()
            .cloned()
            .ok_or_else(|| Error::invalid("block-native cache without a pool"))?;
        kv.attach_native_tables(pool).map_err(|e| Error::saturated(e.to_string()))
    }

    /// `(blocks per table row, trash block id)` for blocktab operands.
    fn blocktab_geometry(&self, arch: &ModelArch) -> Result<(usize, i32)> {
        let bs = self
            .manifest
            .kv_block
            .ok_or_else(|| Error::invalid("block-native artifacts without kv_block"))?;
        let p = self
            .manifest
            .pool_blocks
            .ok_or_else(|| Error::invalid("block-native artifacts without pool_blocks"))?;
        Ok((arch.cache_len / bs, p as i32))
    }

    /// Take an arch's device pool arrays out of the cache for a blocktab
    /// call (they are donated operands), zero-initializing them on first
    /// use. If the call then fails, the arrays stay absent and the next
    /// call re-creates them zeroed — every in-flight cache on this engine
    /// is invalidated, which matches the dense path's behaviour where a
    /// failed execution consumes the donated KV buffers.
    fn take_pools(&self, arch: &ModelArch) -> Result<Vec<PjRtBuffer>> {
        if let Some(bufs) = self.pool_dev.borrow_mut().remove(&arch.name) {
            return Ok(bufs);
        }
        let bs = self
            .manifest
            .kv_block
            .ok_or_else(|| Error::invalid("block-native artifacts without kv_block"))?;
        let p = self
            .manifest
            .pool_blocks
            .ok_or_else(|| Error::invalid("block-native artifacts without pool_blocks"))?;
        let dims = [p + 1, arch.n_heads, bs, arch.head_dim];
        let zeros = vec![0f32; dims.iter().product()];
        let mut bufs = Vec::with_capacity(arch.n_kv());
        for _ in 0..arch.n_kv() {
            bufs.push(self.client.buffer_from_host_buffer(&zeros, &dims, None)?);
        }
        log_info!(
            "device KV pool for '{}': {} arrays of [{} {} {} {}] f32",
            arch.name,
            arch.n_kv(),
            p + 1,
            arch.n_heads,
            bs,
            arch.head_dim
        );
        Ok(bufs)
    }

    fn put_pools(&self, arch: &ModelArch, bufs: Vec<PjRtBuffer>) {
        self.pool_dev.borrow_mut().insert(arch.name.clone(), bufs);
    }

    // ------------------------------------------------------------ plumbing

    fn program(&self, arch: &ModelArch, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        let key = format!("{}:{name}", arch.name);
        if let Some(exe) = self.exes.borrow().get(&key) {
            return Ok(Rc::clone(exe));
        }
        let rel = arch.program_path(name)?;
        let path = self.manifest.dir.join(rel);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::invalid("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut s = self.stats.borrow_mut();
            s.compiles += 1;
            s.compile_wall_s += dt;
        }
        log_debug!("compiled {key} in {dt:.2}s");
        self.exes.borrow_mut().insert(key, Rc::clone(&exe));
        Ok(exe)
    }

    /// Warm the executable cache for a checkpoint's hot-path programs.
    pub fn warmup(&self, ckpt: &str, batches: &[usize]) -> Result<()> {
        let arch = self.manifest.arch_for_checkpoint(ckpt)?.clone();
        self.program(&arch, "prefill_b1")?;
        if self.block_native.get() {
            // block-native hot path: adopt/copy/stepper over table operands
            // (gather/broadcast/compact/merge never run in this mode)
            let body = if arch.kind == "lm" { "decode_blocktab" } else { "score_blocktab" };
            for &b in batches {
                let b = self.manifest.batch_variant(b)?;
                self.program(&arch, &format!("{body}_b{b}"))?;
                self.program(&arch, &format!("adopt_blocktab_b{b}"))?;
                self.program(&arch, &format!("copy_blocktab_b{b}"))?;
            }
            let _ = self.weights_for(ckpt)?;
            return Ok(());
        }
        let body = if arch.kind == "lm" { "decode" } else { "score" };
        for &b in batches {
            let b = self.manifest.batch_variant(b)?;
            self.program(&arch, &format!("{body}_b{b}"))?;
            self.program(&arch, &format!("gather_b{b}"))?;
            self.program(&arch, &format!("broadcast_b{b}"))?;
            if arch.has_program(&format!("compact_b{b}")) {
                self.program(&arch, &format!("compact_b{b}"))?;
            }
        }
        let _ = self.weights_for(ckpt)?;
        Ok(())
    }

    fn weights_for(&self, ckpt: &str) -> Result<Rc<Vec<PjRtBuffer>>> {
        if let Some(w) = self.weights.borrow().get(ckpt) {
            return Ok(Rc::clone(w));
        }
        let arch = self.manifest.arch_for_checkpoint(ckpt)?;
        let rel = arch
            .weights
            .get(ckpt)
            .ok_or_else(|| Error::invalid(format!("no weights for '{ckpt}'")))?;
        let path = self.manifest.dir.join(rel);
        let bytes = std::fs::read(&path)?;
        let total: usize = arch.weight_specs.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        if bytes.len() != total * 4 {
            return Err(Error::invalid(format!(
                "weights {}: got {} bytes, expected {} f32",
                path.display(),
                bytes.len(),
                total
            )));
        }
        let mut floats = vec![0f32; total];
        for (i, ch) in bytes.chunks_exact(4).enumerate() {
            floats[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
        }
        let mut bufs = Vec::with_capacity(arch.weight_specs.len());
        let mut off = 0;
        for (_, shape) in &arch.weight_specs {
            let n: usize = shape.iter().product();
            bufs.push(self.client.buffer_from_host_buffer(&floats[off..off + n], shape, None)?);
            off += n;
        }
        self.stats.borrow_mut().host_bytes_up += bytes.len() as u64;
        log_info!("uploaded weights '{ckpt}' ({total} f32)");
        let rc = Rc::new(bufs);
        self.weights.borrow_mut().insert(ckpt.to_string(), Rc::clone(&rc));
        Ok(rc)
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.stats.borrow_mut().host_bytes_up += (data.len() * 4) as u64;
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn buf_u32(&self, data: &[u32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.stats.borrow_mut().host_bytes_up += (data.len() * 4) as u64;
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.stats.borrow_mut().host_bytes_up += (data.len() * 4) as u64;
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn run(&self, exe: &PjRtLoadedExecutable, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        let t0 = Instant::now();
        let mut out = exe.execute_b_untuple(args)?;
        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.execute_wall_s += t0.elapsed().as_secs_f64();
        if out.is_empty() || out[0].is_empty() {
            return Err(Error::Xla("execution produced no outputs".into()));
        }
        Ok(out.remove(0))
    }

    fn download_i32(&self, buf: &PjRtBuffer) -> Result<Vec<i32>> {
        let lit = buf.to_literal_sync()?;
        let v = lit.to_vec::<i32>()?;
        self.stats.borrow_mut().host_bytes_down += (v.len() * 4) as u64;
        Ok(v)
    }

    fn download_f32(&self, buf: &PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf.to_literal_sync()?;
        let v = lit.to_vec::<f32>()?;
        self.stats.borrow_mut().host_bytes_down += (v.len() * 4) as u64;
        Ok(v)
    }

    /// Fold one cache's junk-vs-spent position counts into the live
    /// utilization gauge (taken right before each decode/score call, where
    /// the junk actually costs attention bandwidth).
    fn observe_cache(&self, kv: &KvSet) {
        let (spent, valid_total, _) = kv.junk_stats();
        let mut s = self.stats.borrow_mut();
        s.cache_positions += spent as u64;
        s.junk_positions += spent.saturating_sub(valid_total) as u64;
    }

    fn pad_prompt(&self, prompt: &[i32]) -> Result<(Vec<i32>, i32)> {
        let pad = self.manifest.prompt_pad;
        if prompt.len() > pad {
            return Err(Error::invalid(format!(
                "prompt of {} tokens exceeds PROMPT_PAD {pad}",
                prompt.len()
            )));
        }
        let mut toks = prompt.to_vec();
        toks.resize(pad, crate::tokenizer::PAD);
        Ok((toks, prompt.len() as i32))
    }

    // --------------------------------------------------------------- calls

    /// LM prefill at b=1: returns last-token logits and the prompt KV cache.
    pub fn lm_prefill(&self, ckpt: &str, prompt: &[i32]) -> Result<(Vec<f32>, KvSet)> {
        let arch = self.manifest.arch_for_checkpoint(ckpt)?.clone();
        if arch.kind != "lm" {
            return Err(Error::invalid(format!("'{ckpt}' is not an LM checkpoint")));
        }
        let exe = self.program(&arch, "prefill_b1")?;
        let w = self.weights_for(ckpt)?;
        let (toks, len) = self.pad_prompt(prompt)?;
        let t = self.buf_i32(&toks, &[1, toks.len()])?;
        let l = self.buf_i32(&[len], &[1])?;
        let mut args: Vec<&PjRtBuffer> = w.iter().collect();
        args.push(&t);
        args.push(&l);
        let mut out = self.run(&exe, &args)?;
        if out.len() != 1 + arch.n_kv() {
            return Err(Error::Xla(format!(
                "prefill returned {} outputs, expected {}",
                out.len(),
                1 + arch.n_kv()
            )));
        }
        let logits = self.download_f32(&out[0])?;
        let kv_bufs: Vec<PjRtBuffer> = out.drain(1..).collect();
        let mut kv = KvSet::new(kv_bufs, 1, arch.cache_len);
        kv.pos_phys = self.manifest.prompt_pad;
        kv.commit(0, 0, prompt.len());
        // block-native: the b=1 prompt cache stays dense — broadcast
        // adopts it into the device pool through a fresh block table
        if !self.block_native.get() {
            self.attach_pages(&mut kv)?;
        }
        Ok((logits, kv))
    }

    /// PRM prefill at b=1 (no logits output).
    pub fn prm_prefill(&self, ckpt: &str, prompt: &[i32]) -> Result<KvSet> {
        let arch = self.manifest.arch_for_checkpoint(ckpt)?.clone();
        if arch.kind != "prm" {
            return Err(Error::invalid(format!("'{ckpt}' is not a PRM checkpoint")));
        }
        let exe = self.program(&arch, "prefill_b1")?;
        let w = self.weights_for(ckpt)?;
        let (toks, len) = self.pad_prompt(prompt)?;
        let t = self.buf_i32(&toks, &[1, toks.len()])?;
        let l = self.buf_i32(&[len], &[1])?;
        let mut args: Vec<&PjRtBuffer> = w.iter().collect();
        args.push(&t);
        args.push(&l);
        let out = self.run(&exe, &args)?;
        if out.len() != arch.n_kv() {
            return Err(Error::Xla(format!(
                "prm prefill returned {} outputs, expected {}",
                out.len(),
                arch.n_kv()
            )));
        }
        let mut kv = KvSet::new(out, 1, arch.cache_len);
        kv.pos_phys = self.manifest.prompt_pad;
        kv.commit(0, 0, prompt.len());
        if !self.block_native.get() {
            self.attach_pages(&mut kv)?;
        }
        Ok(kv)
    }

    /// Broadcast a b=1 prompt cache into `n` beam slots (rounded up to an
    /// exported batch variant). Device-side replicate + bookkeeping copy.
    /// Block-native: every replica gets a freshly allocated table and the
    /// `adopt_blocktab_bN` program scatters the dense prefill rows into
    /// the device pool through it — the only copy the prompt ever takes.
    pub fn kv_broadcast(&self, ckpt: &str, kv: &KvSet, n: usize) -> Result<KvSet> {
        let arch = self.manifest.arch_for_checkpoint(ckpt)?.clone();
        let b = self.manifest.batch_variant(n)?;
        if self.block_native.get() {
            let mut new = KvSet::new(Vec::new(), b, arch.cache_len);
            new.pos_phys = kv.pos_phys;
            let (pos_log, valid) = kv.broadcast_bookkeeping(b);
            new.pos_log = pos_log;
            new.valid = valid;
            self.attach_native(&mut new)?;
            let (nbl, trash) = self.blocktab_geometry(&arch)?;
            let exe = self.program(&arch, &format!("adopt_blocktab_b{b}"))?;
            let tab = self.buf_i32(&new.table_operand(nbl, trash), &[b, nbl])?;
            let pools = self.take_pools(&arch)?;
            let mut args: Vec<&PjRtBuffer> = vec![&tab];
            args.extend(kv.bufs.iter());
            args.extend(pools.iter());
            let out = self.run(&exe, &args)?;
            if out.len() != arch.n_kv() {
                return Err(Error::Xla(format!("adopt returned {} outputs", out.len())));
            }
            self.put_pools(&arch, out);
            return Ok(new);
        }
        let exe = self.program(&arch, &format!("broadcast_b{b}"))?;
        let args: Vec<&PjRtBuffer> = kv.bufs.iter().collect();
        let out = self.run(&exe, &args)?;
        let mut new = KvSet::new(out, b, arch.cache_len);
        new.pos_phys = kv.pos_phys;
        let (pos_log, valid) = kv.broadcast_bookkeeping(b);
        new.pos_log = pos_log;
        new.valid = valid;
        // paged: replicas fork slot 0's table — shared blocks, no growth
        new.pages = kv.broadcast_pages(b);
        Ok(new)
    }

    /// Run `copy_blocktab_b{dst}` moving pool rows from the cache's tables
    /// gathered along `idx` into `fresh`'s (freshly allocated, unshared)
    /// tables. The table operands are host-built; the device only copies
    /// rows pool-to-pool.
    fn blocktab_copy(
        &self,
        arch: &ModelArch,
        kv: &KvSet,
        fresh: &super::kv::PagedKv,
        idx: &[i32],
    ) -> Result<()> {
        let (nbl, trash) = self.blocktab_geometry(arch)?;
        let full = kv.table_operand(nbl, trash);
        let mut src = vec![trash; idx.len() * nbl];
        for (d, &s) in idx.iter().enumerate() {
            let s = s as usize;
            src[d * nbl..(d + 1) * nbl].copy_from_slice(&full[s * nbl..(s + 1) * nbl]);
        }
        let dst = fresh.operand(nbl, trash);
        let exe = self.program(arch, &format!("copy_blocktab_b{}", idx.len()))?;
        let t0 = Instant::now();
        let sb = self.buf_i32(&src, &[idx.len(), nbl])?;
        let db = self.buf_i32(&dst, &[idx.len(), nbl])?;
        let pools = self.take_pools(arch)?;
        let mut args: Vec<&PjRtBuffer> = vec![&sb, &db];
        args.extend(pools.iter());
        let out = self.run(&exe, &args)?;
        if out.len() != arch.n_kv() {
            return Err(Error::Xla(format!("copy returned {} outputs", out.len())));
        }
        self.put_pools(arch, out);
        let mut s = self.stats.borrow_mut();
        s.gather_calls += 1;
        s.gather_wall_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Permute beam slots on device: `new[slot] = old[idx[slot]]`.
    pub fn kv_gather(&self, ckpt: &str, kv: &mut KvSet, idx: &[i32]) -> Result<()> {
        let arch = self.manifest.arch_for_checkpoint(ckpt)?.clone();
        if idx.len() != kv.batch {
            return Err(Error::invalid(format!(
                "gather idx len {} != batch {}",
                idx.len(),
                kv.batch
            )));
        }
        if kv.block_native() {
            let fresh =
                kv.gather_fresh_tables(idx).map_err(|e| Error::saturated(e.to_string()))?;
            self.blocktab_copy(&arch, kv, &fresh, idx)?;
            kv.permute_host(idx);
            kv.pages = Some(fresh);
            return Ok(());
        }
        let exe = self.program(&arch, &format!("gather_b{}", kv.batch))?;
        let t0 = Instant::now();
        let i = self.buf_i32(idx, &[idx.len()])?;
        let mut args: Vec<&PjRtBuffer> = vec![&i];
        args.extend(kv.bufs.iter());
        let out = self.run(&exe, &args)?;
        {
            let mut s = self.stats.borrow_mut();
            s.gather_calls += 1;
            s.gather_wall_s += t0.elapsed().as_secs_f64();
        }
        kv.bufs = out;
        kv.permute_bookkeeping(idx);
        Ok(())
    }

    /// Move beam slots between batch variants: `new[slot] = old[idx[slot]]`
    /// with `idx.len() == dst_batch`. This is the device half of two-tier
    /// batching (shrink to b2 for completion, grow back to b1 at expansion).
    pub fn kv_resize(&self, ckpt: &str, kv: &KvSet, idx: &[i32], dst_batch: usize) -> Result<KvSet> {
        let arch = self.manifest.arch_for_checkpoint(ckpt)?.clone();
        if idx.len() != dst_batch {
            return Err(Error::invalid("resize idx len must equal dst batch"));
        }
        if kv.block_native() {
            let fresh =
                kv.gather_fresh_tables(idx).map_err(|e| Error::saturated(e.to_string()))?;
            self.blocktab_copy(&arch, kv, &fresh, idx)?;
            let mut new = KvSet::new(Vec::new(), dst_batch, arch.cache_len);
            new.pos_phys = kv.pos_phys;
            copy_bookkeeping(kv, &mut new, idx);
            new.pages = Some(fresh);
            return Ok(new);
        }
        let exe = if dst_batch == kv.batch {
            // same-variant: plain gather into a fresh KvSet
            self.program(&arch, &format!("gather_b{}", kv.batch))?
        } else {
            self.program(&arch, &format!("resize_b{}_to_b{}", kv.batch, dst_batch))?
        };
        let t0 = Instant::now();
        let i = self.buf_i32(idx, &[idx.len()])?;
        let mut args: Vec<&PjRtBuffer> = vec![&i];
        args.extend(kv.bufs.iter());
        let out = self.run(&exe, &args)?;
        {
            let mut s = self.stats.borrow_mut();
            s.gather_calls += 1;
            s.gather_wall_s += t0.elapsed().as_secs_f64();
        }
        let mut new = KvSet::new(out, dst_batch, arch.cache_len);
        new.pos_phys = kv.pos_phys;
        copy_bookkeeping(kv, &mut new, idx);
        new.pages = kv.gather_pages(idx);
        Ok(new)
    }

    /// Merge two caches of the same model into one batch (gang batching):
    /// `new[slot] = concat(a, b)[idx[slot]]` with `idx` indexing the union
    /// `[0, a.batch + b.batch)`. The destination is the exported merge
    /// variant for `(a.batch, b.batch)`; the exporter only emits the
    /// `a.batch >= b.batch` half of the grid, so callers merge
    /// largest-first. The merged frontier is `max` of the two — the
    /// laggard's unwritten gap stays junk under its validity rows.
    pub fn kv_merge(&self, ckpt: &str, a: &KvSet, b: &KvSet, idx: &[i32]) -> Result<KvSet> {
        let arch = self.manifest.arch_for_checkpoint(ckpt)?.clone();
        if a.batch < b.batch {
            return Err(Error::invalid(format!(
                "kv_merge wants the larger cache first (got {} < {})",
                a.batch, b.batch
            )));
        }
        let c = self.manifest.merge_variant(a.batch, b.batch)?;
        if idx.len() != c {
            return Err(Error::invalid(format!(
                "merge idx len {} != merge variant {c}",
                idx.len()
            )));
        }
        if a.block_native() && b.block_native() {
            // block-native: the K/V rows already live in the shared device
            // pool — the union cache is just the members' tables
            // concatenated along `idx`. No device call, nothing copied.
            let new = KvSet::merge_tables(a, b, idx)
                .ok_or_else(|| Error::invalid("table merge on incompatible caches"))?;
            self.stats.borrow_mut().table_merges += 1;
            return Ok(new);
        }
        let exe = self.program(&arch, &format!("merge_b{}_b{}_to_b{c}", a.batch, b.batch))?;
        let t0 = Instant::now();
        let i = self.buf_i32(idx, &[idx.len()])?;
        let mut args: Vec<&PjRtBuffer> = vec![&i];
        args.extend(a.bufs.iter());
        args.extend(b.bufs.iter());
        let out = self.run(&exe, &args)?;
        {
            let mut s = self.stats.borrow_mut();
            s.merge_calls += 1;
            s.merge_wall_s += t0.elapsed().as_secs_f64();
        }
        let mut new = KvSet::new(out, c, arch.cache_len);
        let (pos_phys, pos_log, valid) = KvSet::merge_bookkeeping(a, b, idx);
        new.pos_phys = pos_phys;
        new.pos_log = pos_log;
        new.valid = valid;
        // paged: the union's tables fork the members' along the same
        // index — gang merge becomes block-table concatenation
        new.pages = KvSet::merge_pages(a, b, idx);
        Ok(new)
    }

    /// Extract one request's contiguous slot range `[start, start + dst_batch)`
    /// out of a merged cache back into its own batch variant — the inverse
    /// of [`Engine::kv_merge`] after a ganged decode/score call. Reuses the
    /// `resize`/`gather` programs, so it needs nothing new exported.
    pub fn kv_split(
        &self,
        ckpt: &str,
        merged: &KvSet,
        start: usize,
        dst_batch: usize,
    ) -> Result<KvSet> {
        if start + dst_batch > merged.batch {
            return Err(Error::invalid(format!(
                "split [{start}, {}) out of merged batch {}",
                start + dst_batch,
                merged.batch
            )));
        }
        if merged.block_native() {
            // block-native: forking the member's slice of the union's
            // tables *is* the split — the transient union cache is dropped
            // right after, so the shared refcounts unwind immediately.
            let new = merged
                .split_tables(start, dst_batch)
                .ok_or_else(|| Error::invalid("table split on a non-native cache"))?;
            self.stats.borrow_mut().table_splits += 1;
            return Ok(new);
        }
        let idx: Vec<i32> = (start..start + dst_batch).map(|i| i as i32).collect();
        self.kv_resize(ckpt, merged, &idx, dst_batch)
    }

    /// Re-compact a cache in place: gather every slot's valid positions
    /// down to a dense prefix (device `compact_bN` program, KV buffers
    /// donated) and lower the lockstep frontier to the max dense length,
    /// reclaiming the junk gap merged/diverged writes left behind. The
    /// attendable (position -> K/V) sequence of every slot is preserved
    /// exactly, so the call is semantically invisible to future decodes
    /// and scores. Returns `false` without touching anything when the
    /// artifact set lacks the program (pre-compaction exports) or there
    /// is no junk to reclaim.
    pub fn kv_compact(&self, ckpt: &str, kv: &mut KvSet) -> Result<bool> {
        let arch = self.manifest.arch_for_checkpoint(ckpt)?.clone();
        if kv.block_native() {
            // block-native: valid rows never move — reclaiming the common
            // junk tail is a uniform table truncation, done synchronously
            // on the host with zero device work.
            let (reclaimed, freed) = kv.compact_tables();
            if reclaimed == 0 {
                return Ok(false);
            }
            {
                let mut s = self.stats.borrow_mut();
                s.table_compacts += 1;
                s.compact_reclaimed += reclaimed as u64;
            }
            log_debug!(
                "table-compacted '{ckpt}' b{}: frontier -> {} (+{} positions, {freed} blocks freed)",
                kv.batch,
                kv.pos_phys,
                reclaimed
            );
            return Ok(true);
        }
        let name = format!("compact_b{}", kv.batch);
        if !arch.has_program(&name) {
            return Ok(false);
        }
        let Some(plan) = kv.compact_plan() else {
            return Ok(false);
        };
        let exe = self.program(&arch, &name)?;
        let t0 = Instant::now();
        let i = self.buf_i32(&plan.idx, &[kv.batch, kv.cache_len])?;
        let mut args: Vec<&PjRtBuffer> = vec![&i];
        args.extend(kv.bufs.iter());
        let out = self.run(&exe, &args)?;
        {
            let mut s = self.stats.borrow_mut();
            s.compact_calls += 1;
            s.compact_reclaimed += plan.reclaimed as u64;
            s.gather_calls += 1;
            s.gather_wall_s += t0.elapsed().as_secs_f64();
        }
        kv.bufs = out;
        kv.apply_compact(&plan);
        log_debug!(
            "compacted '{ckpt}' b{}: frontier {} -> {} (+{} positions)",
            kv.batch,
            plan.new_frontier + plan.reclaimed,
            plan.new_frontier,
            plan.reclaimed
        );
        Ok(true)
    }

    /// Sample `decode_block` tokens for every slot. Consumes and replaces
    /// the KV buffers (they are donated to the execution). Caller commits
    /// accepted tokens into the bookkeeping afterwards.
    pub fn lm_decode_block(
        &self,
        ckpt: &str,
        kv: &mut KvSet,
        prev_tok: &[i32],
        temp: f32,
        keys: &[u32],
    ) -> Result<Vec<i32>> {
        let arch = self.manifest.arch_for_checkpoint(ckpt)?.clone();
        let b = kv.batch;
        if prev_tok.len() != b || keys.len() != 2 * b {
            return Err(Error::invalid("decode arg arity mismatch"));
        }
        if kv.remaining() < self.manifest.decode_block {
            return Err(Error::invalid(format!(
                "KV cache exhausted (frontier {} of {})",
                kv.pos_phys, kv.cache_len
            )));
        }
        if kv.block_native() {
            // per-slot write positions — captured *before* the reserve
            // grows the tables (a slot writes at its own frontier, which
            // is its table's pre-write token length)
            let frontiers = kv.slot_frontiers();
            kv.reserve_frontier(self.manifest.decode_block)
                .map_err(|e| Error::saturated(e.to_string()))?;
            let exe = self.program(&arch, &format!("decode_blocktab_b{b}"))?;
            let w = self.weights_for(ckpt)?;
            self.observe_cache(kv);
            let t0 = Instant::now();
            let (nbl, trash) = self.blocktab_geometry(&arch)?;
            let tab = self.buf_i32(&kv.table_operand(nbl, trash), &[b, nbl])?;
            let fr = self.buf_i32(&frontiers, &[b])?;
            let pos_log = self.buf_i32(&kv.pos_log, &[b])?;
            let valid = self.buf_i32(&kv.valid, &[b, kv.cache_len])?;
            let tok = self.buf_i32(prev_tok, &[b])?;
            let t = self.buf_f32(&[temp], &[1])?;
            let k = self.buf_u32(keys, &[b, 2])?;
            let pools = self.take_pools(&arch)?;
            let mut args: Vec<&PjRtBuffer> = w.iter().collect();
            args.extend([&tab, &fr, &pos_log, &valid, &tok, &t, &k]);
            args.extend(pools.iter());
            let mut out = self.run(&exe, &args)?;
            if out.len() != 1 + arch.n_kv() {
                return Err(Error::Xla(format!("decode returned {} outputs", out.len())));
            }
            let tokens = self.download_i32(&out[0])?;
            self.put_pools(&arch, out.drain(1..).collect());
            kv.advance_frontier(self.manifest.decode_block);
            let mut s = self.stats.borrow_mut();
            s.decode_calls += 1;
            let e = s.decode_wall.entry(b).or_default();
            e.calls += 1;
            e.wall_s += t0.elapsed().as_secs_f64();
            return Ok(tokens);
        }
        // paged: reserve the block write up front — exhaustion here is
        // clean backpressure (503), with the cache untouched
        kv.reserve_frontier(self.manifest.decode_block)
            .map_err(|e| Error::saturated(e.to_string()))?;
        let exe = self.program(&arch, &format!("decode_b{b}"))?;
        let w = self.weights_for(ckpt)?;
        self.observe_cache(kv);
        let t0 = Instant::now();
        let pos_phys = self.buf_i32(&[kv.pos_phys as i32], &[1])?;
        let pos_log = self.buf_i32(&kv.pos_log, &[b])?;
        let valid = self.buf_i32(&kv.valid, &[b, kv.cache_len])?;
        let tok = self.buf_i32(prev_tok, &[b])?;
        let t = self.buf_f32(&[temp], &[1])?;
        let k = self.buf_u32(keys, &[b, 2])?;
        let mut args: Vec<&PjRtBuffer> = w.iter().collect();
        args.extend([&pos_phys, &pos_log, &valid, &tok, &t, &k]);
        args.extend(kv.bufs.iter());
        let mut out = self.run(&exe, &args)?;
        {
            let mut s = self.stats.borrow_mut();
            s.decode_calls += 1;
            let e = s.decode_wall.entry(b).or_default();
            e.calls += 1;
            e.wall_s += t0.elapsed().as_secs_f64();
        }
        if out.len() != 1 + arch.n_kv() {
            return Err(Error::Xla(format!("decode returned {} outputs", out.len())));
        }
        let tokens = self.download_i32(&out[0])?;
        kv.bufs = out.drain(1..).collect();
        kv.advance_frontier(self.manifest.decode_block);
        Ok(tokens)
    }

    /// Score `score_block` new tokens per slot with the PRM. `tokens` is
    /// row-major `[batch, score_block]` (PAD beyond each slot's span).
    pub fn prm_score_block(
        &self,
        ckpt: &str,
        kv: &mut KvSet,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let arch = self.manifest.arch_for_checkpoint(ckpt)?.clone();
        let b = kv.batch;
        let t = self.manifest.score_block;
        if tokens.len() != b * t {
            return Err(Error::invalid("score tokens arity mismatch"));
        }
        if kv.remaining() < t {
            return Err(Error::invalid(format!(
                "PRM KV cache exhausted (frontier {} of {})",
                kv.pos_phys, kv.cache_len
            )));
        }
        if kv.block_native() {
            let frontiers = kv.slot_frontiers();
            kv.reserve_frontier(t).map_err(|e| Error::saturated(e.to_string()))?;
            let exe = self.program(&arch, &format!("score_blocktab_b{b}"))?;
            let w = self.weights_for(ckpt)?;
            self.observe_cache(kv);
            let t0 = Instant::now();
            let (nbl, trash) = self.blocktab_geometry(&arch)?;
            let tab = self.buf_i32(&kv.table_operand(nbl, trash), &[b, nbl])?;
            let fr = self.buf_i32(&frontiers, &[b])?;
            let pos_log = self.buf_i32(&kv.pos_log, &[b])?;
            let valid = self.buf_i32(&kv.valid, &[b, kv.cache_len])?;
            let toks = self.buf_i32(tokens, &[b, t])?;
            let pools = self.take_pools(&arch)?;
            let mut args: Vec<&PjRtBuffer> = w.iter().collect();
            args.extend([&tab, &fr, &pos_log, &valid, &toks]);
            args.extend(pools.iter());
            let mut out = self.run(&exe, &args)?;
            if out.len() != 1 + arch.n_kv() {
                return Err(Error::Xla(format!("score returned {} outputs", out.len())));
            }
            let scores = self.download_f32(&out[0])?;
            self.put_pools(&arch, out.drain(1..).collect());
            kv.advance_frontier(t);
            let mut s = self.stats.borrow_mut();
            s.score_calls += 1;
            let e = s.score_wall.entry(b).or_default();
            e.calls += 1;
            e.wall_s += t0.elapsed().as_secs_f64();
            return Ok(scores);
        }
        kv.reserve_frontier(t).map_err(|e| Error::saturated(e.to_string()))?;
        let exe = self.program(&arch, &format!("score_b{b}"))?;
        let w = self.weights_for(ckpt)?;
        self.observe_cache(kv);
        let t0 = Instant::now();
        let pos_phys = self.buf_i32(&[kv.pos_phys as i32], &[1])?;
        let pos_log = self.buf_i32(&kv.pos_log, &[b])?;
        let valid = self.buf_i32(&kv.valid, &[b, kv.cache_len])?;
        let toks = self.buf_i32(tokens, &[b, t])?;
        let mut args: Vec<&PjRtBuffer> = w.iter().collect();
        args.extend([&pos_phys, &pos_log, &valid, &toks]);
        args.extend(kv.bufs.iter());
        let mut out = self.run(&exe, &args)?;
        {
            let mut s = self.stats.borrow_mut();
            s.score_calls += 1;
            let e = s.score_wall.entry(b).or_default();
            e.calls += 1;
            e.wall_s += t0.elapsed().as_secs_f64();
        }
        if out.len() != 1 + arch.n_kv() {
            return Err(Error::Xla(format!("score returned {} outputs", out.len())));
        }
        let scores = self.download_f32(&out[0])?;
        kv.bufs = out.drain(1..).collect();
        kv.advance_frontier(t);
        Ok(scores)
    }

    /// Whole-sequence PRM scoring through the Pallas prefix-score kernel.
    /// `tokens` is row-major `[fullseq_batch, seq_train]`.
    /// Returns (score, cummin, cummean), each `[fullseq_batch * seq_train]`.
    pub fn prm_fullseq(
        &self,
        ckpt: &str,
        tokens: &[i32],
        lens: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let arch = self.manifest.arch_for_checkpoint(ckpt)?.clone();
        let fb = self.manifest.fullseq_batch;
        let s = self.manifest.seq_train;
        if tokens.len() != fb * s || lens.len() != fb {
            return Err(Error::invalid(format!(
                "fullseq expects [{fb}, {s}] tokens and [{fb}] lens"
            )));
        }
        let exe = self.program(&arch, &format!("fullseq_b{fb}"))?;
        let w = self.weights_for(ckpt)?;
        let t = self.buf_i32(tokens, &[fb, s])?;
        let l = self.buf_i32(lens, &[fb])?;
        let mut args: Vec<&PjRtBuffer> = w.iter().collect();
        args.push(&t);
        args.push(&l);
        let out = self.run(&exe, &args)?;
        if out.len() != 3 {
            return Err(Error::Xla(format!("fullseq returned {} outputs", out.len())));
        }
        Ok((
            self.download_f32(&out[0])?,
            self.download_f32(&out[1])?,
            self.download_f32(&out[2])?,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_merge_accumulates() {
        let mut a = EngineStats {
            executions: 2,
            decode_calls: 1,
            score_calls: 1,
            merge_calls: 0,
            compact_calls: 1,
            compact_reclaimed: 8,
            table_merges: 2,
            table_splits: 3,
            table_compacts: 1,
            junk_positions: 4,
            cache_positions: 16,
            compiles: 1,
            compile_wall_s: 0.5,
            execute_wall_s: 1.0,
            host_bytes_up: 100,
            host_bytes_down: 10,
            pool_blocks_total: 64,
            pool_blocks_free: 48,
            pool_hwm: 20,
            ..EngineStats::default()
        };
        a.decode_wall.insert(8, CallWall { calls: 2, wall_s: 0.2 });
        let mut b = EngineStats {
            executions: 3,
            decode_calls: 2,
            score_calls: 0,
            merge_calls: 4,
            compact_calls: 2,
            compact_reclaimed: 3,
            table_merges: 4,
            table_splits: 4,
            table_compacts: 2,
            junk_positions: 2,
            cache_positions: 8,
            merge_wall_s: 0.4,
            gather_calls: 5,
            gather_wall_s: 0.1,
            compiles: 0,
            compile_wall_s: 0.25,
            execute_wall_s: 2.0,
            host_bytes_up: 50,
            host_bytes_down: 5,
            pool_blocks_total: 64,
            pool_blocks_free: 60,
            pool_hwm: 4,
            ..EngineStats::default()
        };
        b.decode_wall.insert(8, CallWall { calls: 1, wall_s: 0.1 });
        b.decode_wall.insert(16, CallWall { calls: 1, wall_s: 0.3 });
        b.score_wall.insert(4, CallWall { calls: 1, wall_s: 0.05 });
        a.merge(&b);
        assert_eq!(a.executions, 5);
        assert_eq!(a.decode_calls, 3);
        assert_eq!(a.score_calls, 1);
        assert_eq!(a.merge_calls, 4);
        assert_eq!(a.compact_calls, 3);
        assert_eq!(a.compact_reclaimed, 11);
        assert_eq!(a.table_merges, 6);
        assert_eq!(a.table_splits, 7);
        assert_eq!(a.table_compacts, 3);
        assert_eq!(a.junk_positions, 6);
        assert_eq!(a.cache_positions, 24);
        assert!((a.junk_fraction() - 0.25).abs() < 1e-12);
        assert_eq!(a.decode_wall[&8].calls, 3);
        assert!((a.decode_wall[&8].wall_s - 0.3).abs() < 1e-12);
        assert!((a.decode_wall[&8].mean_s() - 0.1).abs() < 1e-12);
        assert_eq!(a.decode_wall[&16].calls, 1);
        assert_eq!(a.score_wall[&4].calls, 1);
        assert!((a.merge_wall_s - 0.4).abs() < 1e-12);
        assert_eq!(a.gather_calls, 5);
        assert_eq!(a.compiles, 1);
        assert!((a.compile_wall_s - 0.75).abs() < 1e-12);
        assert!((a.execute_wall_s - 3.0).abs() < 1e-12);
        assert_eq!(a.host_bytes_up, 150);
        assert_eq!(a.host_bytes_down, 15);
        assert_eq!(a.pool_blocks_total, 128, "per-shard pools sum to a fleet total");
        assert_eq!(a.pool_blocks_free, 108);
        assert_eq!(a.pool_hwm, 24);
    }

    #[test]
    fn stats_merge_identity() {
        let mut a = EngineStats::default();
        a.merge(&EngineStats::default());
        assert_eq!(a.executions, 0);
        assert_eq!(a.host_bytes_up, 0);
        assert_eq!(a.junk_fraction(), 0.0, "no positions observed yet");
        assert_eq!(a.compact_calls, 0);
        assert!(a.decode_wall.is_empty());
    }
}
