//! Shared KV block pool + per-beam block tables (paged KV allocation).
//!
//! The dense cache discipline gives every slot `cache_len` physical
//! positions up front, so a rejected beam's memory is only reclaimed by
//! re-compaction and `--max-inflight` is bounded by worst-case cache
//! length. Paged allocation replaces that with vLLM-style indirection:
//!
//! * a [`BlockPool`] owns a fixed population of fixed-size blocks with a
//!   LIFO free list and per-block refcounts (refcounts > 1 are shared
//!   blocks — the copy-on-write foundation for prefix sharing);
//! * each beam slot holds a [`BlockTable`] mapping its logical cache
//!   positions `[0, len)` to `(block, offset)` pairs, so beam
//!   permute/merge/split/compact become table edits (retain/release on
//!   block ids) instead of device-wide gathers;
//! * freeing a rejected beam is [`BlockTable::release_all`] — its blocks
//!   return to the pool in the same scheduler tick, ready for the next
//!   request.
//!
//! The pool is host-side bookkeeping: it decides *which* physical block a
//! logical position lives in; the device realization is the block-granular
//! `*_paged_bN` / `gather_blocks_bN` programs exported by
//! `python/compile/aot.py` (dense artifacts keep working — paging degrades
//! gracefully when those programs are absent).
//!
//! Invariants (pinned by the property battery below):
//! * `free + allocated == total` after any op sequence — no leak, and a
//!   double-release panics rather than corrupting the free list;
//! * a table's logical→physical mapping preserves the attendable sequence
//!   in order (translate is monotone within a block and blocks never
//!   alias while exclusively owned);
//! * fork/merge/truncate commute with reads the same way the dense
//!   `compact_plan` properties pin for gathers.

use std::cell::RefCell;
use std::rc::Rc;

/// Index of a block inside its pool.
pub type BlockId = u32;

/// The pool could not cover a reservation; callers degrade to queueing
/// (HTTP 503 / fleet admission back-off), never to corruption.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolExhausted {
    pub wanted_blocks: usize,
    pub free_blocks: usize,
}

impl std::fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "kv block pool exhausted: wanted {} blocks, {} free",
            self.wanted_blocks, self.free_blocks
        )
    }
}

impl std::error::Error for PoolExhausted {}

/// Point-in-time pool gauges (`/metrics`, `fleet_benchmark`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub blocks_total: usize,
    pub blocks_free: usize,
    /// High-water mark of simultaneously allocated blocks.
    pub hwm: usize,
    pub block_size: usize,
}

/// Fixed population of fixed-size KV blocks with refcounted ownership.
#[derive(Debug)]
pub struct BlockPool {
    block_size: usize,
    /// Refcount per block; 0 = on the free list.
    refs: Vec<u32>,
    /// LIFO free list (hot blocks stay cache-warm on reuse).
    free: Vec<BlockId>,
    hwm: usize,
}

impl BlockPool {
    pub fn new(total_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0, "block size must be positive");
        BlockPool {
            block_size,
            refs: vec![0; total_blocks],
            free: (0..total_blocks as BlockId).rev().collect(),
            hwm: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn total(&self) -> usize {
        self.refs.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn allocated(&self) -> usize {
        self.total() - self.free.len()
    }

    /// High-water mark of simultaneously allocated blocks.
    pub fn hwm(&self) -> usize {
        self.hwm
    }

    /// Blocks needed to cover `tokens` positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            blocks_total: self.total(),
            blocks_free: self.free_blocks(),
            hwm: self.hwm,
            block_size: self.block_size,
        }
    }

    /// Take one block (refcount 1). `None` when the pool is exhausted.
    pub fn alloc(&mut self) -> Option<BlockId> {
        let b = self.free.pop()?;
        debug_assert_eq!(self.refs[b as usize], 0, "free-list block had a live refcount");
        self.refs[b as usize] = 1;
        self.hwm = self.hwm.max(self.allocated());
        Some(b)
    }

    /// Share an allocated block (copy-on-write fork).
    pub fn retain(&mut self, b: BlockId) {
        let r = &mut self.refs[b as usize];
        assert!(*r > 0, "retain of a free block {b}");
        *r += 1;
    }

    /// Drop one reference; the block returns to the free list when the
    /// last owner releases it. Releasing a free block is a double-free —
    /// panic rather than corrupt the free list.
    pub fn release(&mut self, b: BlockId) {
        let r = &mut self.refs[b as usize];
        assert!(*r > 0, "double free of block {b}");
        *r -= 1;
        if *r == 0 {
            self.free.push(b);
        }
    }

    /// Current refcount (tests / diagnostics).
    pub fn refcount(&self, b: BlockId) -> u32 {
        self.refs[b as usize]
    }
}

/// Shared handle: one pool per engine shard, threaded through every cache
/// the shard owns. The engine is `!Send`-confined to its thread, so
/// `Rc<RefCell<..>>` is the right ownership (no cross-thread sharing).
pub type SharedPool = Rc<RefCell<BlockPool>>;

/// Build a shared pool.
pub fn shared_pool(total_blocks: usize, block_size: usize) -> SharedPool {
    Rc::new(RefCell::new(BlockPool::new(total_blocks, block_size)))
}

/// One beam slot's logical→physical mapping: logical position `p` lives at
/// `(blocks[p / block_size], p % block_size)`.
#[derive(Debug, Default, Clone)]
pub struct BlockTable {
    blocks: Vec<BlockId>,
    /// Logical positions mapped (the slot's cache frontier).
    len: usize,
}

impl BlockTable {
    pub fn new() -> Self {
        BlockTable::default()
    }

    pub fn blocks(&self) -> &[BlockId] {
        &self.blocks
    }

    /// Mapped logical positions.
    pub fn len_tokens(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Positions the current blocks can hold without another reservation.
    pub fn capacity(&self, block_size: usize) -> usize {
        self.blocks.len() * block_size
    }

    /// Translate a logical position to `(block, offset)`. `None` past the
    /// mapped frontier.
    pub fn translate(&self, pos: usize, block_size: usize) -> Option<(BlockId, usize)> {
        if pos >= self.len {
            return None;
        }
        Some((self.blocks[pos / block_size], pos % block_size))
    }

    /// Grow the mapping to cover `[0, upto_tokens)`, allocating blocks as
    /// needed. All-or-nothing: on exhaustion the blocks grabbed by *this
    /// call* go straight back and the table is unchanged, so a failed
    /// reservation can simply be retried after other work frees blocks.
    pub fn reserve(&mut self, pool: &mut BlockPool, upto_tokens: usize) -> Result<(), PoolExhausted> {
        let need = pool.blocks_for(upto_tokens);
        if need > self.blocks.len() {
            let missing = need - self.blocks.len();
            if missing > pool.free_blocks() {
                return Err(PoolExhausted {
                    wanted_blocks: missing,
                    free_blocks: pool.free_blocks(),
                });
            }
            for _ in 0..missing {
                let b = pool.alloc().expect("free count checked above");
                self.blocks.push(b);
            }
        }
        self.len = self.len.max(upto_tokens);
        Ok(())
    }

    /// Shrink the mapped frontier to `new_len` tokens, releasing blocks
    /// that no longer back any mapped position (a compaction's table
    /// edit: the device repack moved the attendable sequence into the
    /// dense prefix, the tail blocks return to the pool).
    pub fn truncate(&mut self, pool: &mut BlockPool, new_len: usize) {
        if new_len >= self.len {
            return;
        }
        let keep = pool.blocks_for(new_len);
        while self.blocks.len() > keep {
            let b = self.blocks.pop().expect("len checked");
            pool.release(b);
        }
        self.len = new_len;
    }

    /// Release every block (the beam died / the cache dropped). The table
    /// is empty afterwards; the blocks are reusable the moment this
    /// returns — same-tick reclamation is the paged design's point.
    pub fn release_all(&mut self, pool: &mut BlockPool) {
        for b in self.blocks.drain(..) {
            pool.release(b);
        }
        self.len = 0;
    }

    /// Share this table's blocks into a new table (beam expand / gather
    /// duplicating a slot): O(blocks) refcount bumps, no device copy.
    /// Writers must un-share before mutating a block (copy-on-write; the
    /// lockstep coordinator only appends at fresh blocks, so shared
    /// prefixes stay immutable).
    pub fn fork(&self, pool: &mut BlockPool) -> BlockTable {
        for &b in &self.blocks {
            pool.retain(b);
        }
        BlockTable { blocks: self.blocks.clone(), len: self.len }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, check_simple, shrink_vec, Config};

    #[test]
    fn alloc_free_round_trip() {
        let mut pool = BlockPool::new(4, 16);
        assert_eq!(pool.free_blocks(), 4);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(pool.allocated(), 2);
        assert_eq!(pool.hwm(), 2);
        pool.release(a);
        assert_eq!(pool.free_blocks(), 3);
        pool.release(b);
        assert_eq!(pool.free_blocks(), 4);
        assert_eq!(pool.hwm(), 2, "hwm survives the frees");
    }

    #[test]
    fn exhaustion_returns_none_never_corrupts() {
        let mut pool = BlockPool::new(2, 8);
        let a = pool.alloc().unwrap();
        let _b = pool.alloc().unwrap();
        assert_eq!(pool.alloc(), None);
        pool.release(a);
        assert!(pool.alloc().is_some(), "freed block is immediately reusable");
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut pool = BlockPool::new(2, 8);
        let a = pool.alloc().unwrap();
        pool.release(a);
        pool.release(a);
    }

    #[test]
    #[should_panic(expected = "retain of a free block")]
    fn retain_free_block_panics() {
        let mut pool = BlockPool::new(2, 8);
        pool.retain(0);
    }

    #[test]
    fn refcount_shares_and_releases() {
        let mut pool = BlockPool::new(2, 8);
        let a = pool.alloc().unwrap();
        pool.retain(a);
        assert_eq!(pool.refcount(a), 2);
        pool.release(a);
        assert_eq!(pool.free_blocks(), 1, "still one owner");
        pool.release(a);
        assert_eq!(pool.free_blocks(), 2, "last release frees");
    }

    #[test]
    fn table_reserve_translate_truncate() {
        let mut pool = BlockPool::new(8, 4);
        let mut t = BlockTable::new();
        assert_eq!(t.translate(0, 4), None, "empty table maps nothing");
        t.reserve(&mut pool, 6).unwrap();
        assert_eq!(t.len_tokens(), 6);
        assert_eq!(t.blocks().len(), 2);
        let (b0, o0) = t.translate(0, 4).unwrap();
        let (b1, o1) = t.translate(5, 4).unwrap();
        assert_eq!((b0, o0), (t.blocks()[0], 0));
        assert_eq!((b1, o1), (t.blocks()[1], 1));
        assert_eq!(t.translate(6, 4), None, "past the frontier");
        t.truncate(&mut pool, 3);
        assert_eq!(t.blocks().len(), 1, "tail block released");
        assert_eq!(pool.free_blocks(), 7);
        t.release_all(&mut pool);
        assert_eq!(pool.free_blocks(), 8);
        assert!(t.is_empty());
    }

    #[test]
    fn failed_reserve_is_all_or_nothing() {
        let mut pool = BlockPool::new(2, 4);
        let mut t = BlockTable::new();
        let err = t.reserve(&mut pool, 12).unwrap_err();
        assert_eq!(err.wanted_blocks, 3);
        assert_eq!(err.free_blocks, 2);
        assert_eq!(pool.free_blocks(), 2, "nothing leaked by the failed call");
        assert!(t.is_empty(), "table unchanged");
        t.reserve(&mut pool, 8).unwrap();
        assert_eq!(t.blocks().len(), 2, "retry after the failure succeeds");
    }

    #[test]
    fn fork_shares_blocks_by_refcount() {
        let mut pool = BlockPool::new(4, 4);
        let mut t = BlockTable::new();
        t.reserve(&mut pool, 8).unwrap();
        let mut u = t.fork(&mut pool);
        assert_eq!(t.blocks(), u.blocks(), "fork maps the same physical blocks");
        assert_eq!(pool.allocated(), 2, "no new blocks allocated by the fork");
        t.release_all(&mut pool);
        assert_eq!(pool.allocated(), 2, "fork keeps the blocks alive");
        assert_eq!(u.translate(5, 4).unwrap().1, 1);
        u.release_all(&mut pool);
        assert_eq!(pool.free_blocks(), 4);
    }

    // ------------------------------------------------ property battery

    /// Arbitrary op sequences never leak or double-free:
    /// `free + allocated == total` holds after every step, refcounts
    /// stay consistent with table ownership, and releasing everything
    /// restores the full free list.
    #[test]
    fn prop_pool_conserves_blocks_under_arbitrary_ops() {
        #[derive(Debug, Clone)]
        enum Op {
            Reserve(usize, usize), // (table, upto_tokens)
            Truncate(usize, usize),
            Fork(usize, usize), // (src, dst) — dst releases its blocks first
            Free(usize),
        }
        check(
            "pool-conservation",
            Config::default(),
            |rng| {
                let n_tables = 1 + rng.below(4);
                let ops: Vec<Op> = (0..rng.below(24))
                    .map(|_| match rng.below(4) {
                        0 => Op::Reserve(rng.below(n_tables), rng.below(40)),
                        1 => Op::Truncate(rng.below(n_tables), rng.below(40)),
                        2 => Op::Fork(rng.below(n_tables), rng.below(n_tables)),
                        _ => Op::Free(rng.below(n_tables)),
                    })
                    .collect();
                (n_tables, ops)
            },
            |&(n_tables, ref ops)| {
                let mut pool = BlockPool::new(16, 4);
                let mut tables: Vec<BlockTable> = (0..n_tables).map(|_| BlockTable::new()).collect();
                for op in ops {
                    match *op {
                        Op::Reserve(t, upto) => {
                            let _ = tables[t].reserve(&mut pool, upto);
                        }
                        Op::Truncate(t, len) => {
                            let new_len = len.min(tables[t].len_tokens());
                            tables[t].truncate(&mut pool, new_len);
                        }
                        Op::Fork(src, dst) => {
                            if src != dst {
                                let forked = tables[src].fork(&mut pool);
                                tables[dst].release_all(&mut pool);
                                tables[dst] = forked;
                            }
                        }
                        Op::Free(t) => tables[t].release_all(&mut pool),
                    }
                    if pool.free_blocks() + pool.allocated() != pool.total() {
                        return Err(format!(
                            "conservation broken: {} free + {} allocated != {}",
                            pool.free_blocks(),
                            pool.allocated(),
                            pool.total()
                        ));
                    }
                    if pool.hwm() > pool.total() {
                        return Err("hwm above pool size".into());
                    }
                }
                // total refcount must equal the tables' block holdings
                let held: usize = tables.iter().map(|t| t.blocks().len()).sum();
                let refs: usize = (0..pool.total() as BlockId)
                    .map(|b| pool.refcount(b) as usize)
                    .sum();
                if held != refs {
                    return Err(format!("tables hold {held} block refs, pool counts {refs}"));
                }
                for t in &mut tables {
                    t.release_all(&mut pool);
                }
                if pool.free_blocks() != pool.total() {
                    return Err(format!(
                        "leak: {} of {} blocks free after releasing every table",
                        pool.free_blocks(),
                        pool.total()
                    ));
                }
                Ok(())
            },
            |&(n_tables, ref ops)| {
                shrink_vec(ops).into_iter().map(|o| (n_tables, o)).collect()
            },
        );
    }

    /// The logical→physical mapping preserves the attendable sequence in
    /// order: walking logical positions 0..len through `translate` visits
    /// block offsets monotonically within each block, never revisits a
    /// (block, offset) cell, and survives fork/truncate edits — the paged
    /// analogue of `prop_compact_preserves_attendable_sequence`.
    #[test]
    fn prop_table_mapping_preserves_sequence_order() {
        check_simple(
            "table-order",
            |rng| {
                let bs = 1 + rng.below(8);
                let grows: Vec<usize> = (0..1 + rng.below(6)).map(|_| rng.below(20)).collect();
                (bs, grows)
            },
            |&(bs, ref grows)| {
                let mut pool = BlockPool::new(64, bs);
                let mut t = BlockTable::new();
                let mut len = 0usize;
                for &g in grows {
                    len = len.max(g.min(64 * bs));
                    t.reserve(&mut pool, len).map_err(|e| e.to_string())?;
                }
                let mut seen = std::collections::HashSet::new();
                let mut prev: Option<(BlockId, usize)> = None;
                for p in 0..len {
                    let Some((blk, off)) = t.translate(p, bs) else {
                        return Err(format!("mapped position {p} failed to translate"));
                    };
                    if off != p % bs {
                        return Err(format!("offset {off} != {p} % {bs}"));
                    }
                    if !seen.insert((blk, off)) {
                        return Err(format!("cell ({blk},{off}) aliased twice"));
                    }
                    if let Some((pb, po)) = prev {
                        let same_block = pb == blk;
                        if same_block && off != po + 1 {
                            return Err("non-contiguous walk within a block".into());
                        }
                        if !same_block && (po != bs - 1 || off != 0) {
                            return Err("block boundary crossed mid-block".into());
                        }
                    }
                    prev = Some((blk, off));
                }
                // a fork reads the identical sequence through shared blocks
                let f = t.fork(&mut pool);
                for p in 0..len {
                    if f.translate(p, bs) != t.translate(p, bs) {
                        return Err(format!("fork diverged at position {p}"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Paged permute/merge/compact commute with gather, mirroring the
    /// dense `compact_plan` battery: permuting tables (fork along an
    /// index vector) then reading equals reading then permuting; merge is
    /// table concatenation; truncate (compact) never changes surviving
    /// positions' mapping below the new frontier.
    #[test]
    fn prop_table_edits_commute_with_gather() {
        check_simple(
            "table-edits-commute",
            |rng| {
                let bs = 1 + rng.below(4);
                let batch = 1 + rng.below(4);
                let lens: Vec<usize> = (0..batch).map(|_| rng.below(16)).collect();
                let perm: Vec<usize> = (0..batch).map(|_| rng.below(batch)).collect();
                let cut = rng.below(16);
                (bs, lens, perm, cut)
            },
            |&(bs, ref lens, ref perm, cut)| {
                let mut pool = BlockPool::new(256, bs);
                let mut tables: Vec<BlockTable> = Vec::new();
                for &l in lens {
                    let mut t = BlockTable::new();
                    t.reserve(&mut pool, l).map_err(|e| e.to_string())?;
                    tables.push(t);
                }
                let read = |t: &BlockTable| -> Vec<(BlockId, usize)> {
                    (0..t.len_tokens()).map(|p| t.translate(p, bs).unwrap()).collect()
                };
                // permute = per-slot fork along the index vector (the
                // table edit that replaces the device-wide gather_bN)
                let permuted: Vec<BlockTable> =
                    perm.iter().map(|&src| tables[src].fork(&mut pool)).collect();
                for (dst, &src) in perm.iter().enumerate() {
                    if read(&permuted[dst]) != read(&tables[src]) {
                        return Err(format!("permute diverged at dst {dst} (src {src})"));
                    }
                }
                // merge = concatenation of the two sides' tables; every
                // member keeps its own mapping verbatim
                let merged: Vec<&BlockTable> = tables.iter().chain(permuted.iter()).collect();
                for (i, m) in merged.iter().enumerate() {
                    let src = if i < tables.len() { &tables[i] } else { &permuted[i - tables.len()] };
                    if read(m) != read(src) {
                        return Err(format!("merge slot {i} lost its mapping"));
                    }
                }
                // compact = truncate; the surviving prefix maps unchanged
                let mut cut_table = tables[0].fork(&mut pool);
                let before = read(&cut_table);
                let new_len = cut.min(cut_table.len_tokens());
                cut_table.truncate(&mut pool, new_len);
                let after = read(&cut_table);
                if after[..] != before[..new_len] {
                    return Err("truncate disturbed the surviving prefix".into());
                }
                // cleanup without leaks (conservation re-checked here)
                cut_table.release_all(&mut pool);
                for mut t in permuted {
                    t.release_all(&mut pool);
                }
                for t in &mut tables {
                    t.release_all(&mut pool);
                }
                if pool.free_blocks() != pool.total() {
                    return Err("leak after releasing every table".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn shared_pool_handle_round_trips() {
        let pool = shared_pool(4, 8);
        let mut t = BlockTable::new();
        t.reserve(&mut pool.borrow_mut(), 10).unwrap();
        assert_eq!(pool.borrow().allocated(), 2);
        let s = pool.borrow().stats();
        assert_eq!(s.blocks_total, 4);
        assert_eq!(s.blocks_free, 2);
        assert_eq!(s.hwm, 2);
        assert_eq!(s.block_size, 8);
        t.release_all(&mut pool.borrow_mut());
        assert_eq!(pool.borrow().free_blocks(), 4);
    }
}
