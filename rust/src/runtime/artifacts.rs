//! Artifacts manifest: the ABI between `python/compile/aot.py` and this
//! runtime. Parsed from `artifacts/manifest.json`; validated against the
//! Rust tokenizer so the two sides can never disagree silently.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::tokenizer;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// One model architecture (lm / prm-large / prm-small) with its programs
/// and available weight checkpoints.
#[derive(Debug, Clone)]
pub struct ModelArch {
    pub name: String,
    pub kind: String, // "lm" | "prm"
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub cache_len: usize,
    pub params: u64,
    pub flops_per_token: u64,
    /// (name, shape) in weights.bin / HLO argument order.
    pub weight_specs: Vec<(String, Vec<usize>)>,
    /// program name -> HLO text path (relative to artifacts dir).
    pub programs: BTreeMap<String, PathBuf>,
    /// checkpoint name -> weights.bin path.
    pub weights: BTreeMap<String, PathBuf>,
}

impl ModelArch {
    /// Number of KV-cache arrays threaded through decode/score calls.
    pub fn n_kv(&self) -> usize {
        2 * self.n_layers
    }

    pub fn n_weights(&self) -> usize {
        self.weight_specs.len()
    }

    pub fn program_path(&self, name: &str) -> Result<&PathBuf> {
        self.programs
            .get(name)
            .ok_or_else(|| Error::invalid(format!("model '{}' has no program '{name}'", self.name)))
    }

    /// Whether the artifact set exported a given program. The gang
    /// batcher probes this to degrade gracefully on artifacts built
    /// before the merge programs existed.
    pub fn has_program(&self, name: &str) -> bool {
        self.programs.contains_key(name)
    }

    /// Whether `merge_bA_bB_to_bC` exists for a source pair (a >= b).
    pub fn has_merge(&self, a: usize, b: usize, c: usize) -> bool {
        self.has_program(&format!("merge_b{a}_b{b}_to_b{c}"))
    }

    /// Whether the block-native program set exists for one batch variant:
    /// the pool install (`adopt_blocktab`), the pool row copy
    /// (`copy_blocktab`), and the arch's own stepper (`decode_blocktab`
    /// for LMs, `score_blocktab` for PRMs) — the calls that replace the
    /// gather-bracketed paged path.
    pub fn has_blocktab(&self, b: usize) -> bool {
        let stepper = if self.kind == "lm" { "decode_blocktab" } else { "score_blocktab" };
        self.has_program(&format!("adopt_blocktab_b{b}"))
            && self.has_program(&format!("copy_blocktab_b{b}"))
            && self.has_program(&format!("{stepper}_b{b}"))
    }

    /// Block-native readiness over a whole variant ladder: every exported
    /// batch width must have its blocktab programs, or the engine falls
    /// back to the gather-bracketed paged mode for *all* widths (mixing
    /// modes per-width would break merge/split table-edit invariants).
    pub fn block_native_ready(&self, variants: &[usize]) -> bool {
        !variants.is_empty() && variants.iter().all(|&b| self.has_blocktab(b))
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub vocab: Vec<String>,
    pub prompt_pad: usize,
    pub decode_block: usize,
    pub score_block: usize,
    pub seq_train: usize,
    pub batch_variants: Vec<usize>,
    pub fullseq_batch: usize,
    /// Paged-KV block size in tokens. `None` on artifact sets exported
    /// before paging existed — the runtime then keeps the dense
    /// fixed-length discipline (graceful fallback, no error).
    pub kv_block: Option<usize>,
    /// Device pool size (blocks) the block-native programs were exported
    /// against: the pool arrays are `[pool_blocks + 1, ...]` with the last
    /// row as the trash row. `None` on artifact sets without the blocktab
    /// programs; also the geometry-derived default for `--kv-pool-blocks`.
    pub pool_blocks: Option<usize>,
    pub models: BTreeMap<String, ModelArch>,
    /// Paper-scale parameter counts (narrative comparison only).
    pub paper_scale: BTreeMap<String, f64>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path).map_err(|e| {
            Error::Io(std::io::Error::new(
                e.kind(),
                format!("{}: {e} (run `make artifacts` first)", path.display()),
            ))
        })?;
        let j = Json::parse(&src)?;
        let vocab: Vec<String> = j
            .req("vocab")?
            .as_arr()
            .ok_or_else(|| Error::parse("vocab must be an array"))?
            .iter()
            .map(|v| v.as_str().unwrap_or("").to_string())
            .collect();

        let mut models = BTreeMap::new();
        for (name, m) in j.req("models")?.as_obj().ok_or_else(|| Error::parse("models"))? {
            models.insert(name.clone(), parse_model(name, m)?);
        }
        let mut paper_scale = BTreeMap::new();
        if let Some(ps) = j.get("paper_scale").and_then(Json::as_obj) {
            for (k, v) in ps {
                paper_scale.insert(k.clone(), v.as_f64().unwrap_or(0.0));
            }
        }
        let man = Manifest {
            dir: dir.to_path_buf(),
            vocab,
            prompt_pad: j.req("prompt_pad")?.as_usize().unwrap_or(16),
            decode_block: j.req("decode_block")?.as_usize().unwrap_or(4),
            score_block: j.req("score_block")?.as_usize().unwrap_or(16),
            seq_train: j.req("seq_train")?.as_usize().unwrap_or(256),
            batch_variants: j
                .req("batch_variants")?
                .as_arr()
                .ok_or_else(|| Error::parse("batch_variants"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            fullseq_batch: j.req("fullseq_batch")?.as_usize().unwrap_or(8),
            kv_block: j.get("kv_block").and_then(Json::as_usize).filter(|&b| b > 0),
            pool_blocks: j.get("pool_blocks").and_then(Json::as_usize).filter(|&b| b > 0),
            models,
            paper_scale,
        };
        man.validate_abi()?;
        Ok(man)
    }

    /// The Python-side vocabulary must match the Rust tokenizer exactly.
    fn validate_abi(&self) -> Result<()> {
        let ours = tokenizer::token_strs();
        if self.vocab.len() != ours.len() {
            return Err(Error::invalid(format!(
                "vocab size mismatch: manifest {} vs tokenizer {}",
                self.vocab.len(),
                ours.len()
            )));
        }
        for (i, (a, b)) in self.vocab.iter().zip(ours.iter()).enumerate() {
            if a != b {
                return Err(Error::invalid(format!(
                    "vocab mismatch at id {i}: manifest '{a}' vs tokenizer '{b}'"
                )));
            }
        }
        if self.batch_variants.is_empty() {
            return Err(Error::invalid("no batch variants exported"));
        }
        Ok(())
    }

    pub fn model(&self, name: &str) -> Result<&ModelArch> {
        self.models
            .get(name)
            .ok_or_else(|| Error::invalid(format!("unknown model '{name}'")))
    }

    /// Model arch that owns a given checkpoint (e.g. "lm-concise" -> "lm").
    pub fn arch_for_checkpoint(&self, ckpt: &str) -> Result<&ModelArch> {
        self.models
            .values()
            .find(|m| m.weights.contains_key(ckpt))
            .ok_or_else(|| Error::invalid(format!("no model has checkpoint '{ckpt}'")))
    }

    /// The batch a `merge_bA_bB` program lands in: the smallest exported
    /// variant holding both source batches' slots. (The exporter pins the
    /// destination per (a, b) pair, so this is the ABI, not a heuristic.)
    pub fn merge_variant(&self, a: usize, b: usize) -> Result<usize> {
        self.batch_variant(a + b)
    }

    /// Smallest exported batch variant >= n.
    pub fn batch_variant(&self, n: usize) -> Result<usize> {
        self.batch_variants
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .ok_or_else(|| {
                Error::invalid(format!(
                    "no batch variant >= {n} (have {:?})",
                    self.batch_variants
                ))
            })
    }
}

fn parse_model(name: &str, m: &Json) -> Result<ModelArch> {
    let specs = m
        .req("weight_specs")?
        .as_arr()
        .ok_or_else(|| Error::parse("weight_specs"))?
        .iter()
        .map(|e| {
            let pair = e.as_arr().ok_or_else(|| Error::parse("weight spec entry"))?;
            let nm = pair[0].as_str().ok_or_else(|| Error::parse("weight name"))?;
            let shape = pair[1]
                .as_arr()
                .ok_or_else(|| Error::parse("weight shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            Ok((nm.to_string(), shape))
        })
        .collect::<Result<Vec<_>>>()?;
    let mut programs = BTreeMap::new();
    for (k, v) in m.req("programs")?.as_obj().ok_or_else(|| Error::parse("programs"))? {
        programs.insert(k.clone(), PathBuf::from(v.as_str().unwrap_or("")));
    }
    let mut weights = BTreeMap::new();
    for (k, v) in m.req("weights")?.as_obj().ok_or_else(|| Error::parse("weights"))? {
        weights.insert(k.clone(), PathBuf::from(v.as_str().unwrap_or("")));
    }
    Ok(ModelArch {
        name: name.to_string(),
        kind: m.req("kind")?.as_str().unwrap_or("").to_string(),
        d_model: m.req("d_model")?.as_usize().unwrap_or(0),
        n_layers: m.req("n_layers")?.as_usize().unwrap_or(0),
        n_heads: m.req("n_heads")?.as_usize().unwrap_or(0),
        head_dim: m.req("head_dim")?.as_usize().unwrap_or(0),
        ffn: m.req("ffn")?.as_usize().unwrap_or(0),
        vocab: m.req("vocab")?.as_usize().unwrap_or(0),
        cache_len: m.req("cache_len")?.as_usize().unwrap_or(0),
        params: m.req("params")?.as_i64().unwrap_or(0) as u64,
        flops_per_token: m.req("flops_per_token")?.as_i64().unwrap_or(0) as u64,
        weight_specs: specs,
        programs,
        weights,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest_json() -> String {
        let vocab: Vec<String> =
            tokenizer::token_strs().iter().map(|s| format!("\"{}\"", s.replace('"', "\\\""))).collect();
        format!(
            r#"{{
  "vocab": [{}],
  "prompt_pad": 16, "decode_block": 4, "score_block": 16, "seq_train": 256,
  "mod": 100, "batch_variants": [4, 16, 64], "fullseq_batch": 8,
  "models": {{
    "lm": {{
      "kind": "lm", "d_model": 64, "n_layers": 2, "n_heads": 4, "head_dim": 16,
      "ffn": 256, "vocab": 24, "cache_len": 320, "params": 102016,
      "flops_per_token": 204032,
      "weight_specs": [["emb", [24, 64]], ["head", [64, 24]]],
      "programs": {{"prefill_b1": "hlo/lm_prefill_b1.hlo.txt"}},
      "weights": {{"lm-concise": "weights/lm-concise.bin"}}
    }}
  }},
  "paper_scale": {{"lm": 3e9}}
}}"#,
            vocab.join(",")
        )
    }

    fn load_toy(dir: &std::path::Path) -> Manifest {
        std::fs::write(dir.join("manifest.json"), toy_manifest_json()).unwrap();
        Manifest::load(dir).unwrap()
    }

    #[test]
    fn parses_toy_manifest() {
        let dir = std::env::temp_dir().join("erprm-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        let m = load_toy(&dir);
        assert_eq!(m.prompt_pad, 16);
        assert_eq!(m.kv_block, None, "pre-paging manifests parse without the field");
        let lm = m.model("lm").unwrap();
        assert_eq!(lm.n_kv(), 4);
        assert_eq!(lm.params, 102016);
        assert_eq!(m.arch_for_checkpoint("lm-concise").unwrap().name, "lm");
        assert!(m.model("nope").is_err());
        assert!(m.arch_for_checkpoint("nope").is_err());
    }

    #[test]
    fn batch_variant_rounds_up() {
        let dir = std::env::temp_dir().join("erprm-manifest-test2");
        std::fs::create_dir_all(&dir).unwrap();
        let m = load_toy(&dir);
        assert_eq!(m.batch_variant(1).unwrap(), 4);
        assert_eq!(m.batch_variant(4).unwrap(), 4);
        assert_eq!(m.batch_variant(5).unwrap(), 16);
        assert_eq!(m.batch_variant(64).unwrap(), 64);
        assert!(m.batch_variant(65).is_err());
    }

    #[test]
    fn merge_variant_and_program_probes() {
        let dir = std::env::temp_dir().join("erprm-manifest-test-merge");
        std::fs::create_dir_all(&dir).unwrap();
        let m = load_toy(&dir);
        // toy variants are [4, 16, 64]
        assert_eq!(m.merge_variant(4, 4).unwrap(), 16);
        assert_eq!(m.merge_variant(16, 16).unwrap(), 64);
        assert!(m.merge_variant(64, 4).is_err(), "no variant can hold 68 slots");
        let lm = m.model("lm").unwrap();
        assert!(lm.has_program("prefill_b1"));
        assert!(!lm.has_program("merge_b4_b4_to_b16"));
        assert!(!lm.has_merge(4, 4, 16), "old artifacts lack merge programs");
    }

    #[test]
    fn pool_blocks_and_blocktab_probes() {
        let dir = std::env::temp_dir().join("erprm-manifest-test-blocktab");
        std::fs::create_dir_all(&dir).unwrap();
        let m = load_toy(&dir);
        assert_eq!(m.pool_blocks, None, "pre-blocktab manifests parse without the field");
        let lm = m.model("lm").unwrap();
        assert!(!lm.has_blocktab(4), "old artifacts lack blocktab programs");
        assert!(!lm.block_native_ready(&[4, 16]));
        assert!(!lm.block_native_ready(&[]), "an empty ladder is never ready");
        // inject pool_blocks + the full blocktab program set for b=4
        let src = toy_manifest_json()
            .replacen("\"prompt_pad\": 16", "\"pool_blocks\": 256, \"prompt_pad\": 16", 1)
            .replacen(
                "\"prefill_b1\": \"hlo/lm_prefill_b1.hlo.txt\"",
                "\"prefill_b1\": \"hlo/lm_prefill_b1.hlo.txt\",
                 \"adopt_blocktab_b4\": \"hlo/lm_adopt_blocktab_b4.hlo.txt\",
                 \"copy_blocktab_b4\": \"hlo/lm_copy_blocktab_b4.hlo.txt\",
                 \"decode_blocktab_b4\": \"hlo/lm_decode_blocktab_b4.hlo.txt\"",
                1,
            );
        std::fs::write(dir.join("manifest.json"), src).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.pool_blocks, Some(256));
        let lm = m.model("lm").unwrap();
        assert!(lm.has_blocktab(4));
        assert!(lm.block_native_ready(&[4]));
        assert!(!lm.block_native_ready(&[4, 16]), "one missing width blocks all widths");
    }

    #[test]
    fn kv_block_parses_when_present() {
        let dir = std::env::temp_dir().join("erprm-manifest-test-kvblock");
        std::fs::create_dir_all(&dir).unwrap();
        let src = toy_manifest_json().replacen("\"prompt_pad\": 16", "\"kv_block\": 32, \"prompt_pad\": 16", 1);
        std::fs::write(dir.join("manifest.json"), src).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.kv_block, Some(32));
        // kv_block = 0 is meaningless and reads as "dense"
        let src = toy_manifest_json().replacen("\"prompt_pad\": 16", "\"kv_block\": 0, \"prompt_pad\": 16", 1);
        std::fs::write(dir.join("manifest.json"), src).unwrap();
        assert_eq!(Manifest::load(&dir).unwrap().kv_block, None);
    }

    #[test]
    fn vocab_mismatch_rejected() {
        let dir = std::env::temp_dir().join("erprm-manifest-test3");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = toy_manifest_json().replacen("\"+\"", "\"@\"", 1);
        std::fs::write(dir.join("manifest.json"), bad).unwrap();
        let e = Manifest::load(&dir).unwrap_err();
        assert!(e.to_string().contains("vocab mismatch"));
    }

    #[test]
    fn missing_manifest_mentions_make() {
        let dir = std::env::temp_dir().join("erprm-manifest-none");
        std::fs::create_dir_all(&dir).unwrap();
        let _ = std::fs::remove_file(dir.join("manifest.json"));
        let e = Manifest::load(&dir).unwrap_err();
        assert!(e.to_string().contains("make artifacts"));
    }
}
