//! Analytic simulator of the paper's Section 4 theory.
//!
//! Implements the i.i.d. per-token toy model: beam i's token scores are
//! i.i.d. with mean mu_i and std sigma; the partial reward is the sum of
//! the first tau tokens, the final reward the sum of all L. Under this
//! model rho(P, F) = sqrt(tau / L) exactly, and the probability of pruning
//! the best beam obeys the sub-Gaussian bound
//!     Pr[P_best < T] <= (N - 1) exp(-Delta^2 / (4 sigma_tau^2)).
//! The `theory_bounds` bench and `examples/theory_validation.rs` regenerate
//! the paper's Fig. 4 trend and verify the bound empirically.

use crate::util::rng::Rng;
use crate::util::stats;

/// Monte-Carlo correlation of (partial@tau, final@L) under the toy model.
/// All beams share mu=0, sigma=1 (correlation is mean-invariant).
pub fn toy_correlation(tau: usize, l: usize, trials: usize, seed: u64) -> (f64, f64) {
    assert!(tau >= 1 && tau <= l);
    let mut rng = Rng::new(seed);
    let mut partials = Vec::with_capacity(trials);
    let mut finals = Vec::with_capacity(trials);
    for _ in 0..trials {
        let mut p = 0.0;
        let mut f = 0.0;
        for t in 0..l {
            let x = rng.normal();
            f += x;
            if t < tau {
                p += x;
            }
        }
        partials.push(p);
        finals.push(f);
    }
    (stats::pearson(&partials, &finals), stats::kendall_tau(&partials, &finals))
}

/// Closed form rho = sqrt(tau / L).
pub fn toy_correlation_exact(tau: usize, l: usize) -> f64 {
    (tau as f64 / l as f64).sqrt()
}

/// One early-rejection trial: N beams, best beam has per-token mean
/// `delta_token` above the rest; keep the top N/M by partial reward.
/// Returns whether the best beam was (wrongly) pruned.
fn prune_trial(rng: &mut Rng, n: usize, m: usize, tau: usize, delta_token: f64, sigma: f64) -> bool {
    let keep = (n / m).max(1);
    let mut partials = Vec::with_capacity(n);
    for i in 0..n {
        let mu = if i == 0 { delta_token } else { 0.0 };
        let mut p = 0.0;
        for _ in 0..tau {
            p += mu + sigma * rng.normal();
        }
        partials.push(p);
    }
    // rank of beam 0 (the true best)
    let best = partials[0];
    let better = partials[1..].iter().filter(|&&p| p > best).count();
    better >= keep
}

/// Empirical Pr[prune best] and the sub-Gaussian upper bound.
///
/// Bound (Sec. 4): with expected partial-score gap Delta = tau*delta_token
/// and sub-Gaussian parameter sigma_tau = sigma*sqrt(tau):
///   Pr <= (N-1) exp(-Delta^2 / (4 sigma_tau^2))
///       = (N-1) exp(-tau * delta_token^2 / (4 sigma^2)).
pub fn prune_probability(
    n: usize,
    m: usize,
    tau: usize,
    delta_token: f64,
    sigma: f64,
    trials: usize,
    seed: u64,
) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let mut pruned = 0usize;
    for _ in 0..trials {
        if prune_trial(&mut rng, n, m, tau, delta_token, sigma) {
            pruned += 1;
        }
    }
    let empirical = pruned as f64 / trials as f64;
    let bound =
        ((n - 1) as f64) * (-(tau as f64) * delta_token * delta_token / (4.0 * sigma * sigma)).exp();
    (empirical, bound.min(1.0))
}

/// Minimum tau for a target correlation rho* (Sec. 4): tau >= rho*^2 * L.
pub fn min_tau_for_rho(rho_star: f64, l: usize) -> usize {
    // epsilon guards fp noise (0.8^2 * 100 = 64.00000000000001)
    (rho_star * rho_star * l as f64 - 1e-9).ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn correlation_follows_sqrt_law() {
        for &(tau, l) in &[(8usize, 64usize), (16, 64), (32, 64), (64, 64)] {
            let (pearson, _) = toy_correlation(tau, l, 4000, 42);
            let exact = toy_correlation_exact(tau, l);
            assert!(
                (pearson - exact).abs() < 0.05,
                "tau={tau} L={l}: mc {pearson:.3} vs exact {exact:.3}"
            );
        }
    }

    #[test]
    fn correlation_is_one_at_full_length() {
        let (p, k) = toy_correlation(32, 32, 500, 1);
        assert!((p - 1.0).abs() < 1e-9);
        assert!((k - 1.0).abs() < 1e-9);
    }

    #[test]
    fn kendall_increases_with_tau() {
        let (_, k8) = toy_correlation(8, 64, 3000, 7);
        let (_, k32) = toy_correlation(32, 64, 3000, 7);
        assert!(k32 > k8);
    }

    #[test]
    fn bound_holds_and_decays() {
        // wide gap, modest noise: both empirical and bound tiny
        let (emp, bound) = prune_probability(16, 4, 32, 0.5, 1.0, 3000, 9);
        assert!(emp <= bound + 0.02, "empirical {emp} vs bound {bound}");
        // bound decays exponentially in tau (delta large enough that the
        // min(.,1) clamp releases)
        let (_, b8) = prune_probability(16, 4, 8, 1.0, 1.0, 10, 9);
        let (_, b64) = prune_probability(16, 4, 64, 1.0, 1.0, 10, 9);
        assert!(b64 < b8 * 0.1, "b8={b8} b64={b64}");
    }

    #[test]
    fn zero_gap_prunes_often() {
        // with no gap the best beam survives only by luck (keep/N)
        let (emp, _) = prune_probability(16, 4, 16, 0.0, 1.0, 4000, 11);
        let expected = 1.0 - 4.0 / 16.0; // keep 4 of 16
        assert!((emp - expected).abs() < 0.06, "emp {emp} vs {expected}");
    }

    #[test]
    fn min_tau_matches_paper_example() {
        // paper: rho*=0.8 demands tau >= 0.64 L
        assert_eq!(min_tau_for_rho(0.8, 100), 64);
    }
}
