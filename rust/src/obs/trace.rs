//! Per-request trace assembly: spans, instant events, the phase-split
//! FLOPs ledger, and the early-rejection ledger.
//!
//! A [`TraceBuilder`] is plain owned data with no interior locking — it
//! rides inside the request (through `SolveTask` and the fleet job) and
//! every record call is a `Vec` push plus one monotonic-clock read. The
//! only synchronized operation in a request's life is the single
//! [`crate::obs::TraceRecorder::submit`] at completion.
//!
//! Determinism contract: recording never touches RNG streams, beam
//! state, or engine-call order — a traced solve is byte-identical to an
//! untraced one (pinned by the integration suite).

use crate::coordinator::flops::FlopsLedger;
use crate::obs::now_us;
use crate::util::json::Json;

/// A closed (or still-open) interval on the request's timeline.
#[derive(Debug, Clone)]
pub struct Span {
    pub name: &'static str,
    /// Microseconds since the process trace epoch ([`crate::obs::now_us`]).
    pub start_us: u64,
    pub dur_us: u64,
    /// Free-form annotation ("" when none): batch width, gang size, ...
    pub detail: String,
    /// Nesting depth at open time (0 = top level).
    pub depth: usize,
    /// True only while the span is open; a submitted trace must have
    /// every span closed (the well-formedness test pins this).
    pub open: bool,
}

/// A zero-duration marker (admission verdict, cache hit, rejection, ...).
#[derive(Debug, Clone)]
pub struct SpanEvent {
    pub name: &'static str,
    pub ts_us: u64,
    pub detail: String,
}

/// One early-rejection round: which beams died at which depth, their
/// partial scores (kept for later regret analysis against final
/// outcomes), and the estimated FLOPs the rejection saved.
#[derive(Debug, Clone)]
pub struct ErEvent {
    /// Completed select/expand rounds when the rejection fired (the
    /// blocking loop index — rejection depth in paper terms).
    pub depth: usize,
    /// The effective rejection checkpoint this round ran at — `cfg.tau`
    /// unless the adaptive-tau controller resolved a shorter one.
    pub tau: usize,
    /// Beam slots rejected this round.
    pub rejected: Vec<usize>,
    /// Partial rewards of the rejected beams, same order as `rejected`.
    pub scores: Vec<f32>,
    /// Estimated FLOPs not spent because these beams stopped here:
    /// the phase-B completion tokens of this round plus every remaining
    /// round, charged at the ledger's per-token rates for both models.
    /// An upper bound — a rejected beam might have finished early.
    pub flops_saved: f64,
}

impl ErEvent {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("depth", Json::num(self.depth as f64)),
            ("tau", Json::num(self.tau as f64)),
            (
                "rejected",
                Json::Arr(self.rejected.iter().map(|&s| Json::num(s as f64)).collect()),
            ),
            (
                "scores",
                Json::Arr(self.scores.iter().map(|&s| Json::num(s as f64)).collect()),
            ),
            ("flops_saved", Json::num(self.flops_saved)),
        ])
    }
}

/// Calibration payload a request carries out: (depth, partial, final)
/// reward pairs for the observatory, plus the controller/shadow verdicts
/// for the regret ledger. Folded into `obs::calibration::CalibrationHub`
/// by the recorder before sampling — so the table is exact even when the
/// trace ring keeps only a sample of traces.
#[derive(Debug, Clone, Default)]
pub struct CalibNote {
    /// PRM checkpoint that produced the rewards ("" until the first
    /// sample lands).
    pub ckpt: String,
    /// (depth, partial reward at the round's tau, final step reward).
    pub samples: Vec<(u32, f32, f32)>,
    /// The request ran under a controller-resolved plan.
    pub adaptive: bool,
    /// The request ran the shadow regret check.
    pub shadow: bool,
    /// Beams rejected while the shadow comparison was armed.
    pub regret_checked: u64,
    /// Of those, beams the base-tau counterfactual would have kept.
    pub regret: u64,
}

impl CalibNote {
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty() && !self.adaptive && !self.shadow && self.regret_checked == 0
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ckpt", Json::str(&self.ckpt)),
            ("samples", Json::num(self.samples.len() as f64)),
            ("adaptive", Json::Bool(self.adaptive)),
            ("shadow", Json::Bool(self.shadow)),
            ("regret_checked", Json::num(self.regret_checked as f64)),
            ("regret", Json::num(self.regret as f64)),
        ])
    }
}

/// The per-request FLOPs ledger split by lifecycle phase. Derived from
/// the same token counters [`FlopsLedger`] charges, so by construction
/// `prefill + decode + score == FlopsLedger::total_flops()` — the
/// `/solve` response's `flops` field and the trace ledger can never
/// disagree.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseFlops {
    /// LM + PRM prompt ingestion.
    pub prefill: f64,
    /// LM generation tokens.
    pub decode: f64,
    /// PRM scoring tokens.
    pub score: f64,
}

impl PhaseFlops {
    pub fn from_ledger(l: &FlopsLedger) -> PhaseFlops {
        PhaseFlops {
            prefill: l.lm_prefill_tokens as f64 * l.lm_flops_per_token as f64
                + l.prm_prefill_tokens as f64 * l.prm_flops_per_token as f64,
            decode: l.lm_decode_tokens as f64 * l.lm_flops_per_token as f64,
            score: l.prm_score_tokens as f64 * l.prm_flops_per_token as f64,
        }
    }

    pub fn total(&self) -> f64 {
        self.prefill + self.decode + self.score
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("prefill", Json::num(self.prefill)),
            ("decode", Json::num(self.decode)),
            ("score", Json::num(self.score)),
            ("total", Json::num(self.total())),
        ])
    }
}

/// In-flight trace state. Created where the request enters the system,
/// carried by value through the queue / task, sealed with
/// [`TraceBuilder::finish`] and submitted to the recorder exactly once.
#[derive(Debug)]
pub struct TraceBuilder {
    id: String,
    start_us: u64,
    spans: Vec<Span>,
    /// Stack of indices into `spans` that are still open.
    open: Vec<usize>,
    events: Vec<SpanEvent>,
    er: Vec<ErEvent>,
    calib: CalibNote,
    shard: Option<usize>,
    slot: Option<usize>,
    queue_wait_ms: f64,
}

impl TraceBuilder {
    pub fn start(id: impl Into<String>) -> TraceBuilder {
        TraceBuilder {
            id: id.into(),
            start_us: now_us(),
            spans: Vec::new(),
            open: Vec::new(),
            events: Vec::new(),
            er: Vec::new(),
            calib: CalibNote::default(),
            shard: None,
            slot: None,
            queue_wait_ms: 0.0,
        }
    }

    pub fn id(&self) -> &str {
        &self.id
    }

    /// Open a span at the current nesting depth.
    pub fn begin(&mut self, name: &'static str) {
        self.begin_detail(name, String::new());
    }

    pub fn begin_detail(&mut self, name: &'static str, detail: impl Into<String>) {
        let idx = self.spans.len();
        self.spans.push(Span {
            name,
            start_us: now_us(),
            dur_us: 0,
            detail: detail.into(),
            depth: self.open.len(),
            open: true,
        });
        self.open.push(idx);
    }

    /// Close the innermost open span (no-op if none are open — the
    /// error paths call [`TraceBuilder::end_all`] defensively and must
    /// not panic over already-closed spans).
    pub fn end(&mut self) {
        if let Some(idx) = self.open.pop() {
            let s = &mut self.spans[idx];
            s.dur_us = now_us().saturating_sub(s.start_us);
            s.open = false;
        }
    }

    /// Annotate-and-close: replaces the innermost open span's detail.
    pub fn end_detail(&mut self, detail: impl Into<String>) {
        if let Some(&idx) = self.open.last() {
            self.spans[idx].detail = detail.into();
        }
        self.end();
    }

    /// Close every open span — the one call every termination path
    /// (success, error, cancellation, deadline) must make, so no
    /// submitted trace carries an open span.
    pub fn end_all(&mut self) {
        while !self.open.is_empty() {
            self.end();
        }
    }

    pub fn open_spans(&self) -> usize {
        self.open.len()
    }

    pub fn event(&mut self, name: &'static str, detail: impl Into<String>) {
        self.events.push(SpanEvent { name, ts_us: now_us(), detail: detail.into() });
    }

    pub fn reject(&mut self, ev: ErEvent) {
        self.events.push(SpanEvent {
            name: "reject",
            ts_us: now_us(),
            detail: format!("depth={} rejected={}", ev.depth, ev.rejected.len()),
        });
        self.er.push(ev);
    }

    /// Record one (partial, final) calibration pair for the observatory.
    pub fn calib_sample(&mut self, ckpt: &str, depth: u32, partial: f32, final_reward: f32) {
        if self.calib.ckpt.is_empty() {
            self.calib.ckpt = ckpt.to_string();
        }
        self.calib.samples.push((depth, partial, final_reward));
    }

    /// Mark how the controller treated this request (adaptive plan,
    /// shadow regret check).
    pub fn calib_control(&mut self, adaptive: bool, shadow: bool) {
        self.calib.adaptive = adaptive;
        self.calib.shadow = shadow;
    }

    /// Accumulate one shadow-check verdict: `checked` rejected beams, of
    /// which `regret` the base-tau counterfactual would have kept.
    pub fn calib_regret(&mut self, checked: u64, regret: u64) {
        self.calib.regret_checked += checked;
        self.calib.regret += regret;
        self.events.push(SpanEvent {
            name: "shadow",
            ts_us: now_us(),
            detail: format!("checked={checked} regret={regret}"),
        });
    }

    /// Record where the fleet placed this request (Chrome-trace row).
    pub fn set_placement(&mut self, shard: usize, slot: usize) {
        self.shard = Some(shard);
        self.slot = Some(slot);
    }

    pub fn set_queue_wait(&mut self, ms: f64) {
        self.queue_wait_ms = ms;
    }

    /// Seal the trace. Closes any spans an abnormal exit left open.
    pub fn finish(mut self, outcome: &'static str, status: u16, phase: PhaseFlops) -> Trace {
        self.end_all();
        Trace {
            id: self.id,
            outcome,
            status,
            start_us: self.start_us,
            end_us: now_us(),
            shard: self.shard,
            slot: self.slot,
            queue_wait_ms: self.queue_wait_ms,
            spans: self.spans,
            events: self.events,
            er: self.er,
            calib: self.calib,
            phase,
        }
    }
}

/// A completed, immutable request trace as served by `/trace/<id>`.
#[derive(Debug, Clone)]
pub struct Trace {
    pub id: String,
    /// "ok" | "error" | "deadline" | "cancelled" | "cache_hit" | "coalesced".
    pub outcome: &'static str,
    /// HTTP status the request resolved to.
    pub status: u16,
    pub start_us: u64,
    pub end_us: u64,
    pub shard: Option<usize>,
    pub slot: Option<usize>,
    pub queue_wait_ms: f64,
    pub spans: Vec<Span>,
    pub events: Vec<SpanEvent>,
    pub er: Vec<ErEvent>,
    pub calib: CalibNote,
    pub phase: PhaseFlops,
}

impl Trace {
    /// Total estimated FLOPs early rejection saved on this request.
    pub fn er_flops_saved(&self) -> f64 {
        self.er.iter().map(|e| e.flops_saved).sum()
    }

    /// Total beams rejected across all depths.
    pub fn er_rejected(&self) -> usize {
        self.er.iter().map(|e| e.rejected.len()).sum()
    }

    /// Every span closed — true for every trace the builder seals.
    pub fn well_formed(&self) -> bool {
        self.spans.iter().all(|s| !s.open)
    }

    pub fn duration_ms(&self) -> f64 {
        self.end_us.saturating_sub(self.start_us) as f64 / 1000.0
    }

    fn opt_idx(v: Option<usize>) -> Json {
        match v {
            Some(i) => Json::num(i as f64),
            None => Json::Null,
        }
    }

    /// The full per-request document (`GET /trace/<id>`).
    pub fn to_json(&self) -> Json {
        let spans = self
            .spans
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::str(s.name)),
                    ("start_us", Json::num(s.start_us as f64)),
                    ("dur_us", Json::num(s.dur_us as f64)),
                    ("depth", Json::num(s.depth as f64)),
                    ("detail", Json::str(&s.detail)),
                ])
            })
            .collect();
        let events = self
            .events
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("name", Json::str(e.name)),
                    ("ts_us", Json::num(e.ts_us as f64)),
                    ("detail", Json::str(&e.detail)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("request_id", Json::str(&self.id)),
            ("outcome", Json::str(self.outcome)),
            ("status", Json::num(self.status as f64)),
            ("start_us", Json::num(self.start_us as f64)),
            ("duration_ms", Json::num(self.duration_ms())),
            ("queue_wait_ms", Json::num(self.queue_wait_ms)),
            ("shard", Self::opt_idx(self.shard)),
            ("slot", Self::opt_idx(self.slot)),
            ("flops", self.phase.to_json()),
            (
                "early_rejection",
                Json::obj(vec![
                    ("beams_rejected", Json::num(self.er_rejected() as f64)),
                    ("flops_saved", Json::num(self.er_flops_saved())),
                    ("events", Json::Arr(self.er.iter().map(ErEvent::to_json).collect())),
                ]),
            ),
            ("calibration", self.calib.to_json()),
            ("spans", Json::Arr(spans)),
            ("events", Json::Arr(events)),
        ])
    }

    /// The one-line form (`GET /traces`).
    pub fn summary(&self) -> Json {
        Json::obj(vec![
            ("request_id", Json::str(&self.id)),
            ("outcome", Json::str(self.outcome)),
            ("status", Json::num(self.status as f64)),
            ("duration_ms", Json::num(self.duration_ms())),
            ("queue_wait_ms", Json::num(self.queue_wait_ms)),
            ("shard", Self::opt_idx(self.shard)),
            ("slot", Self::opt_idx(self.slot)),
            ("flops", Json::num(self.phase.total())),
            ("beams_rejected", Json::num(self.er_rejected() as f64)),
            ("er_flops_saved", Json::num(self.er_flops_saved())),
            ("spans", Json::num(self.spans.len() as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_nest_and_close() {
        let mut tb = TraceBuilder::start("r1");
        tb.begin("solve");
        tb.begin_detail("decode", "b8");
        assert_eq!(tb.open_spans(), 2);
        tb.end();
        tb.begin("score");
        tb.end();
        tb.end();
        assert_eq!(tb.open_spans(), 0);
        let t = tb.finish("ok", 200, PhaseFlops::default());
        assert!(t.well_formed());
        assert_eq!(t.spans.len(), 3);
        assert_eq!(t.spans[0].depth, 0);
        assert_eq!(t.spans[1].depth, 1);
        assert_eq!(t.spans[1].detail, "b8");
    }

    #[test]
    fn abnormal_exit_closes_open_spans() {
        // error / cancellation / 504 paths leave spans open; finish must
        // seal them so every submitted trace is well-formed
        let mut tb = TraceBuilder::start("r2");
        tb.begin("solve");
        tb.begin("decode");
        let t = tb.finish("error", 504, PhaseFlops::default());
        assert!(t.well_formed());
        assert!(t.spans.iter().all(|s| !s.open));
    }

    #[test]
    fn end_without_open_is_a_noop() {
        let mut tb = TraceBuilder::start("r3");
        tb.end();
        tb.end_all();
        tb.begin("a");
        tb.end();
        tb.end(); // extra
        assert_eq!(tb.open_spans(), 0);
    }

    #[test]
    fn phase_split_sums_to_ledger_total() {
        let mut l = FlopsLedger::new(200, 700);
        l.lm_prefill(10);
        l.lm_decode(90);
        l.prm_prefill(10);
        l.prm_score(40);
        let p = PhaseFlops::from_ledger(&l);
        assert_eq!(p.total(), l.total_flops());
        assert_eq!(p.prefill, 10.0 * 200.0 + 10.0 * 700.0);
        assert_eq!(p.decode, 90.0 * 200.0);
        assert_eq!(p.score, 40.0 * 700.0);
    }

    #[test]
    fn er_ledger_accumulates() {
        let mut tb = TraceBuilder::start("r4");
        tb.reject(ErEvent {
            depth: 0,
            tau: 8,
            rejected: vec![1, 3],
            scores: vec![0.2, 0.1],
            flops_saved: 100.0,
        });
        tb.reject(ErEvent {
            depth: 1,
            tau: 4,
            rejected: vec![2],
            scores: vec![0.4],
            flops_saved: 40.0,
        });
        let t = tb.finish("ok", 200, PhaseFlops::default());
        assert_eq!(t.er_rejected(), 3);
        assert_eq!(t.er_flops_saved(), 140.0);
        // the reject instant events mirror the ledger
        assert_eq!(t.events.iter().filter(|e| e.name == "reject").count(), 2);
    }

    #[test]
    fn calib_note_rides_the_trace() {
        let mut tb = TraceBuilder::start("r6");
        assert!(tb.finish("ok", 200, PhaseFlops::default()).calib.is_empty());
        let mut tb = TraceBuilder::start("r7");
        tb.calib_control(true, true);
        tb.calib_sample("prm-large", 0, 0.6, 0.7);
        tb.calib_sample("prm-large", 1, 0.5, 0.4);
        tb.calib_regret(3, 1);
        tb.calib_regret(2, 0);
        let t = tb.finish("ok", 200, PhaseFlops::default());
        assert_eq!(t.calib.ckpt, "prm-large");
        assert_eq!(t.calib.samples.len(), 2);
        assert!(t.calib.adaptive && t.calib.shadow);
        assert_eq!((t.calib.regret_checked, t.calib.regret), (5, 1));
        assert_eq!(t.events.iter().filter(|e| e.name == "shadow").count(), 2);
        let doc = Json::parse(&t.to_json().to_string()).unwrap();
        let c = doc.get("calibration").unwrap();
        assert_eq!(c.get("regret").and_then(Json::as_f64), Some(1.0));
        assert_eq!(c.get("shadow").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn json_round_trip_parses(){
        let mut tb = TraceBuilder::start("r5");
        tb.begin("solve");
        tb.set_placement(1, 2);
        let t = tb.finish("ok", 200, PhaseFlops { prefill: 1.0, decode: 2.0, score: 3.0 });
        let full = t.to_json().to_string();
        let parsed = Json::parse(&full).unwrap();
        assert_eq!(parsed.get("request_id").and_then(Json::as_str), Some("r5"));
        assert_eq!(
            parsed.get("flops").and_then(|f| f.get("total")).and_then(Json::as_f64),
            Some(6.0)
        );
        let s = Json::parse(&t.summary().to_string()).unwrap();
        assert_eq!(s.get("shard").and_then(Json::as_f64), Some(1.0));
    }
}
