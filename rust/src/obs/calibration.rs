//! Online calibration observatory: streaming partial↔final reward
//! correlation per (PRM checkpoint, depth bucket), and the regret ledger
//! for the adaptive-tau controller built on top of it.
//!
//! Every finished early-rejection request records (depth, partial, final)
//! reward pairs into its trace ([`crate::obs::trace::CalibNote`]); the
//! recorder folds them into this hub before sampling, exactly like the ER
//! rollups — so the table is exact even when the trace ring keeps only a
//! sample. The statistics are the shared incremental kernels from
//! `util::stats` ([`StreamingPearson`] Welford co-moments plus a
//! seed-stable bounded [`StreamingKendall`] reservoir), the same code the
//! offline Fig. 4 study (`harness::correlation`) runs batch-style.
//!
//! The control loop reads a *frozen* snapshot per request: the router
//! resolves a `TauPlan` from [`CalibrationHub::bucket_stats`] before
//! dispatch and the plan never changes mid-request. Aggressiveness is
//! gated on the Fisher-z lower confidence bound of the Pearson estimate —
//! "aggressive where correlation is proven, static `cfg.tau` where
//! samples are thin" — and a sampled shadow check measures regret: beams
//! the effective tau rejected that the base-tau counterfactual would have
//! kept. Surfaces: `GET /calibration` (JSON table), `erprm_calib_*`
//! metrics, and per-request `tau`/`shadow` trace events.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::coordinator::policy::TauPlan;
use crate::obs::metrics::MetricWriter;
use crate::obs::trace::CalibNote;
use crate::util::json::Json;
use crate::util::stats::{StreamingKendall, StreamingPearson};

/// Observatory + controller knobs (`--adaptive-tau`, `--calib-*`,
/// `server.calib_*`), carried through `TraceOptions`/`PoolOptions`.
#[derive(Debug, Clone, Copy)]
pub struct CalibOptions {
    /// Close the loop: let the router resolve per-depth effective taus
    /// from the calibration table. Off = observe only (the table still
    /// streams; every request runs the static `cfg.tau`).
    pub adaptive: bool,
    /// Minimum samples in a bucket before the controller trusts it.
    pub min_samples: u64,
    /// The Fisher-z lower confidence bound on Pearson r must clear this
    /// for a bucket to count as "proven".
    pub conf_floor: f64,
    /// Fraction of the (base − min_tau) span shaved at full confidence
    /// excess, in [0, 1].
    pub aggressiveness: f64,
    /// Hard floor for any effective tau the controller picks.
    pub min_tau: usize,
    /// Fraction of adaptive requests that run a shadow regret check
    /// (decode to base tau, reject at the effective tau, compare).
    pub shadow_rate: f64,
    /// Depth buckets 0..n-1; the last bucket absorbs all deeper rounds.
    pub depth_buckets: usize,
    /// Per-bucket rank-concordance reservoir capacity.
    pub reservoir: usize,
    /// Seed for the reservoir sketch and the shadow draw.
    pub seed: u64,
}

impl Default for CalibOptions {
    fn default() -> Self {
        CalibOptions {
            adaptive: false,
            min_samples: 64,
            conf_floor: 0.35,
            aggressiveness: 0.5,
            min_tau: 2,
            shadow_rate: 0.05,
            depth_buckets: 4,
            reservoir: 256,
            seed: 0xCA11_B8A7E,
        }
    }
}

struct Bucket {
    pearson: StreamingPearson,
    kendall: StreamingKendall,
    /// Last effective tau the controller resolved for this bucket
    /// (0 = controller never ran here).
    tau_effective: u64,
}

#[derive(Default)]
struct HubInner {
    /// (checkpoint, depth bucket) → streaming stats. BTreeMap so every
    /// snapshot/render iterates in one deterministic order.
    buckets: BTreeMap<(String, usize), Bucket>,
    /// Bumped on every mutation batch; the router stamps it into each
    /// request's frozen plan (and its coalescing key), so two requests
    /// sharing a key saw the same table by construction.
    epoch: u64,
    samples_total: u64,
    adaptive_requests: u64,
    shadow_requests: u64,
    regret_checked: u64,
    regret_beams: u64,
}

/// One `/calibration` table row.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibRow {
    pub ckpt: String,
    pub bucket: usize,
    pub samples: u64,
    pub pearson: f64,
    pub kendall: f64,
    /// Fisher-z 95% lower bound on the Pearson estimate (-1 = no
    /// evidence yet).
    pub conf_low: f64,
    /// Clears both the sample floor and the confidence floor.
    pub confident: bool,
    /// Last controller-resolved tau for this bucket (0 = never).
    pub tau_effective: u64,
}

/// A frozen view of the table (`/calibration`, benchmark summaries).
#[derive(Debug, Clone, Default)]
pub struct CalibSnapshot {
    pub epoch: u64,
    pub samples_total: u64,
    pub adaptive_requests: u64,
    pub shadow_requests: u64,
    pub regret_checked: u64,
    pub regret_beams: u64,
    pub rows: Vec<CalibRow>,
}

/// The per-pool observatory. One mutex acquisition per finished request
/// (inside `TraceRecorder::submit`) plus one per adaptive plan resolve.
pub struct CalibrationHub {
    opts: CalibOptions,
    inner: Mutex<HubInner>,
}

const Z95: f64 = 1.96;

fn key_hash(ckpt: &str, bucket: usize) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ bucket as u64;
    for b in ckpt.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

impl CalibrationHub {
    pub fn new(opts: CalibOptions) -> CalibrationHub {
        CalibrationHub { opts, inner: Mutex::new(HubInner::default()) }
    }

    pub fn opts(&self) -> CalibOptions {
        self.opts
    }

    fn bucket_of(&self, depth: usize) -> usize {
        depth.min(self.opts.depth_buckets.max(1) - 1)
    }

    /// Fold one finished request's calibration note into the table.
    /// Called by the recorder for every submitted trace, before sampling.
    pub fn record(&self, note: &CalibNote) {
        if note.is_empty() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        for &(depth, partial, fin) in &note.samples {
            let b = self.bucket_of(depth as usize);
            let bucket = g.buckets.entry((note.ckpt.clone(), b)).or_insert_with(|| Bucket {
                pearson: StreamingPearson::new(),
                kendall: StreamingKendall::new(
                    self.opts.reservoir,
                    self.opts.seed ^ key_hash(&note.ckpt, b),
                ),
                tau_effective: 0,
            });
            bucket.pearson.push(partial as f64, fin as f64);
            bucket.kendall.push(partial as f64, fin as f64);
            g.samples_total += 1;
        }
        g.regret_checked += note.regret_checked;
        g.regret_beams += note.regret;
        if note.shadow {
            g.shadow_requests += 1;
        }
        g.epoch += 1;
    }

    /// Record the plan the controller resolved for a request (feeds the
    /// `erprm_calib_tau_effective` gauge and the adaptive/shadow
    /// counters).
    pub fn note_plan(&self, ckpt: &str, plan: &TauPlan) {
        let mut g = self.inner.lock().unwrap();
        g.adaptive_requests += 1;
        for (b, bt) in plan.by_bucket.iter().enumerate() {
            if let Some(bucket) = g.buckets.get_mut(&(ckpt.to_string(), b)) {
                bucket.tau_effective = bt.tau as u64;
            }
        }
    }

    /// Per-bucket (samples, conf_low) for one checkpoint, indexed by
    /// depth bucket — the `AdaptiveTau` controller's input.
    pub fn bucket_stats(&self, ckpt: &str) -> Vec<(u64, f64)> {
        let g = self.inner.lock().unwrap();
        (0..self.opts.depth_buckets.max(1))
            .map(|b| match g.buckets.get(&(ckpt.to_string(), b)) {
                Some(bu) => (bu.pearson.len(), bu.pearson.corr_lower(Z95)),
                None => (0, -1.0),
            })
            .collect()
    }

    /// Current table epoch (stamped into plans and coalescing keys).
    pub fn epoch(&self) -> u64 {
        self.inner.lock().unwrap().epoch
    }

    pub fn snapshot(&self) -> CalibSnapshot {
        let mut g = self.inner.lock().unwrap();
        let opts = self.opts;
        let mut rows = Vec::with_capacity(g.buckets.len());
        let keys: Vec<(String, usize)> = g.buckets.keys().cloned().collect();
        for k in keys {
            let bu = g.buckets.get_mut(&k).unwrap();
            let n = bu.pearson.len();
            let conf_low = bu.pearson.corr_lower(Z95);
            rows.push(CalibRow {
                ckpt: k.0,
                bucket: k.1,
                samples: n,
                pearson: bu.pearson.corr(),
                kendall: bu.kendall.corr(),
                conf_low,
                confident: n >= opts.min_samples && conf_low >= opts.conf_floor,
                tau_effective: bu.tau_effective,
            });
        }
        CalibSnapshot {
            epoch: g.epoch,
            samples_total: g.samples_total,
            adaptive_requests: g.adaptive_requests,
            shadow_requests: g.shadow_requests,
            regret_checked: g.regret_checked,
            regret_beams: g.regret_beams,
            rows,
        }
    }

    /// The `GET /calibration` document.
    pub fn to_json(&self) -> Json {
        let s = self.snapshot();
        let o = self.opts;
        let rows = s
            .rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("ckpt", Json::str(&r.ckpt)),
                    ("depth_bucket", Json::num(r.bucket as f64)),
                    ("samples", Json::num(r.samples as f64)),
                    ("pearson", Json::num(r.pearson)),
                    ("kendall", Json::num(r.kendall)),
                    ("conf_low", Json::num(r.conf_low)),
                    ("confident", Json::Bool(r.confident)),
                    ("tau_effective", Json::num(r.tau_effective as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("epoch", Json::num(s.epoch as f64)),
            ("adaptive", Json::Bool(o.adaptive)),
            ("samples_total", Json::num(s.samples_total as f64)),
            (
                "knobs",
                Json::obj(vec![
                    ("min_samples", Json::num(o.min_samples as f64)),
                    ("conf_floor", Json::num(o.conf_floor)),
                    ("aggressiveness", Json::num(o.aggressiveness)),
                    ("min_tau", Json::num(o.min_tau as f64)),
                    ("shadow_rate", Json::num(o.shadow_rate)),
                    ("depth_buckets", Json::num(o.depth_buckets as f64)),
                    ("reservoir", Json::num(o.reservoir as f64)),
                ]),
            ),
            (
                "regret",
                Json::obj(vec![
                    ("adaptive_requests", Json::num(s.adaptive_requests as f64)),
                    ("shadow_requests", Json::num(s.shadow_requests as f64)),
                    ("beams_checked", Json::num(s.regret_checked as f64)),
                    ("beams_regretted", Json::num(s.regret_beams as f64)),
                ]),
            ),
            ("buckets", Json::Arr(rows)),
        ])
    }

    /// The observatory's `/metrics` series, exposition-format complete.
    pub fn render_metrics(&self) -> String {
        let s = self.snapshot();
        let mut w = MetricWriter::new();
        for r in &s.rows {
            let labels = format!("ckpt=\"{}\",bucket=\"{}\"", r.ckpt, r.bucket);
            w.gauge_labeled(
                "erprm_calib_corr",
                "Streaming partial-vs-final Pearson correlation per (checkpoint, depth bucket).",
                &labels,
                r.pearson,
            );
        }
        for r in &s.rows {
            let labels = format!("ckpt=\"{}\",bucket=\"{}\"", r.ckpt, r.bucket);
            w.gauge_labeled(
                "erprm_calib_samples",
                "Calibration samples accumulated per (checkpoint, depth bucket).",
                &labels,
                r.samples as f64,
            );
        }
        for r in &s.rows {
            if r.tau_effective == 0 {
                continue;
            }
            let labels = format!("ckpt=\"{}\",bucket=\"{}\"", r.ckpt, r.bucket);
            w.gauge_labeled(
                "erprm_calib_tau_effective",
                "Last controller-resolved effective tau per (checkpoint, depth bucket).",
                &labels,
                r.tau_effective as f64,
            );
        }
        w.gauge(
            "erprm_calib_epoch",
            "Calibration table mutation epoch (stamped into frozen per-request plans).",
            s.epoch as f64,
        );
        w.counter(
            "erprm_calib_adaptive_requests_total",
            "Requests dispatched with a controller-resolved tau plan.",
            s.adaptive_requests as f64,
        );
        w.counter(
            "erprm_calib_shadow_requests_total",
            "Adaptive requests that ran the shadow regret check.",
            s.shadow_requests as f64,
        );
        w.counter(
            "erprm_calib_regret_checked_total",
            "Beams rejected under shadow comparison (the regret denominator).",
            s.regret_checked as f64,
        );
        w.counter(
            "erprm_calib_regret_beams_total",
            "Shadow-checked rejected beams the base-tau counterfactual would have kept.",
            s.regret_beams as f64,
        );
        w.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::AdaptiveTau;
    use crate::obs::metrics::check_exposition;

    fn note(ckpt: &str, samples: &[(u32, f32, f32)]) -> CalibNote {
        CalibNote { ckpt: ckpt.into(), samples: samples.to_vec(), ..CalibNote::default() }
    }

    fn feed_linear(hub: &CalibrationHub, ckpt: &str, depth: u32, n: usize) {
        // perfectly correlated pairs with spread => r = 1, tight bound
        for i in 0..n {
            let v = 0.2 + 0.6 * (i % 13) as f32 / 13.0;
            hub.record(&note(ckpt, &[(depth, v, v)]));
        }
    }

    #[test]
    fn buckets_accumulate_and_clamp_depth() {
        let hub = CalibrationHub::new(CalibOptions { depth_buckets: 3, ..Default::default() });
        hub.record(&note("prm-large", &[(0, 0.5, 0.6), (1, 0.4, 0.5), (9, 0.3, 0.2)]));
        let s = hub.snapshot();
        assert_eq!(s.samples_total, 3);
        assert_eq!(s.epoch, 1, "one mutation batch");
        let buckets: Vec<usize> = s.rows.iter().map(|r| r.bucket).collect();
        assert_eq!(buckets, vec![0, 1, 2], "depth 9 clamps into the last bucket");
        assert!(s.rows.iter().all(|r| r.ckpt == "prm-large"));
    }

    #[test]
    fn confidence_gate_needs_samples_and_correlation() {
        let opts = CalibOptions { min_samples: 32, conf_floor: 0.35, ..Default::default() };
        let hub = CalibrationHub::new(opts);
        feed_linear(&hub, "prm-large", 0, 8);
        assert!(!hub.snapshot().rows[0].confident, "8 samples are thin");
        feed_linear(&hub, "prm-large", 0, 56);
        let r = &hub.snapshot().rows[0];
        assert!(r.samples == 64 && r.confident, "{r:?}");
        assert!(r.pearson > 0.999);
        // an uncorrelated bucket never clears the floor no matter the n
        let mut h = 1u64;
        for _ in 0..200 {
            h = h.wrapping_mul(6364136223846793005).wrapping_add(1);
            let x = (h >> 33) as f32 / (1u32 << 31) as f32;
            let y = (h & 0xffff) as f32 / 65535.0;
            hub.record(&note("prm-large", &[(1, x, y)]));
        }
        let s = hub.snapshot();
        let b1 = s.rows.iter().find(|r| r.bucket == 1).unwrap();
        assert!(!b1.confident, "conf_low {} on noise", b1.conf_low);
    }

    #[test]
    fn bucket_stats_feed_the_controller() {
        let opts = CalibOptions { min_samples: 16, depth_buckets: 3, ..Default::default() };
        let hub = CalibrationHub::new(opts);
        feed_linear(&hub, "prm-large", 1, 64);
        let stats = hub.bucket_stats("prm-large");
        assert_eq!(stats.len(), 3);
        assert_eq!(stats[0], (0, -1.0), "empty bucket carries no evidence");
        assert_eq!(stats[1].0, 64);
        assert!(stats[1].1 > 0.35);
        // other checkpoints see nothing
        assert!(hub.bucket_stats("prm-small").iter().all(|&(n, _)| n == 0));
        // and a resolved plan lands in the tau_effective gauge
        let ctl = AdaptiveTau { min_samples: 16, conf_floor: 0.35, aggressiveness: 1.0, min_tau: 2 };
        let plan = ctl.plan(8, &stats, false, hub.epoch());
        assert!(plan.by_bucket[1].tau < 8, "confident bucket got aggressive");
        hub.note_plan("prm-large", &plan);
        let s = hub.snapshot();
        let row = s.rows.iter().find(|r| r.bucket == 1).unwrap();
        assert_eq!(row.tau_effective, plan.by_bucket[1].tau as u64);
        assert_eq!(s.adaptive_requests, 1);
    }

    #[test]
    fn regret_ledger_rolls_up() {
        let hub = CalibrationHub::new(CalibOptions::default());
        let mut n = note("prm-large", &[(0, 0.5, 0.5)]);
        n.shadow = true;
        n.regret_checked = 6;
        n.regret = 1;
        hub.record(&n);
        hub.record(&n);
        let s = hub.snapshot();
        assert_eq!(s.shadow_requests, 2);
        assert_eq!(s.regret_checked, 12);
        assert_eq!(s.regret_beams, 2);
        let json = hub.to_json().to_string();
        let doc = Json::parse(&json).unwrap();
        let regret = doc.get("regret").unwrap();
        assert_eq!(regret.get("beams_regretted").and_then(Json::as_f64), Some(2.0));
        assert_eq!(doc.get("epoch").and_then(Json::as_f64), Some(2.0));
    }

    #[test]
    fn empty_note_is_a_noop() {
        let hub = CalibrationHub::new(CalibOptions::default());
        hub.record(&CalibNote::default());
        assert_eq!(hub.epoch(), 0);
        assert!(hub.snapshot().rows.is_empty());
    }

    #[test]
    fn metrics_render_is_exposition_valid() {
        let hub = CalibrationHub::new(CalibOptions { min_samples: 8, ..Default::default() });
        feed_linear(&hub, "prm-large", 0, 32);
        feed_linear(&hub, "prm-small", 2, 4);
        let ctl = AdaptiveTau { min_samples: 8, conf_floor: 0.35, aggressiveness: 0.5, min_tau: 2 };
        let stats = hub.bucket_stats("prm-large");
        hub.note_plan("prm-large", &ctl.plan(8, &stats, false, hub.epoch()));
        let text = hub.render_metrics();
        check_exposition(&text).unwrap();
        assert!(text.contains("erprm_calib_corr{ckpt=\"prm-large\",bucket=\"0\"}"), "{text}");
        assert!(text.contains("erprm_calib_samples{ckpt=\"prm-small\",bucket=\"2\"} 4"), "{text}");
        assert!(text.contains("erprm_calib_tau_effective{ckpt=\"prm-large\",bucket=\"0\"}"));
        assert!(text.contains("erprm_calib_regret_beams_total 0"));
        // empty hub renders only the unlabelled series, still valid
        let empty = CalibrationHub::new(CalibOptions::default()).render_metrics();
        check_exposition(&empty).unwrap();
        assert!(!empty.contains("erprm_calib_corr{"));
    }

    #[test]
    fn snapshot_is_deterministic_for_a_given_stream() {
        let run = || {
            let hub = CalibrationHub::new(CalibOptions::default());
            for i in 0..300u32 {
                let v = (i % 17) as f32 / 17.0;
                hub.record(&note("prm-large", &[(i % 5, v, v * 0.8 + 0.1)]));
            }
            let s = hub.snapshot();
            (s.epoch, s.rows.iter().map(|r| (r.pearson, r.kendall, r.samples)).collect::<Vec<_>>())
        };
        assert_eq!(run(), run(), "seed-stable sketch => identical tables");
    }
}
