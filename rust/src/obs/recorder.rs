//! The bounded trace ring buffer and its sampling policy.
//!
//! One recorder per engine pool. `submit` is the only synchronized call
//! on the request path (one mutex acquisition per completed request);
//! reads (`/trace/<id>`, `/traces`, `/traces/chrome`, `/metrics`) clone
//! `Arc<Trace>` handles out under the same lock.
//!
//! Aggregate rollups (requests recorded, early-rejection FLOPs saved)
//! are accumulated for **every** submitted trace, before sampling — the
//! `/metrics` counters stay exact even when the ring keeps only a
//! sample of successful traces.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::obs::calibration::{CalibOptions, CalibrationHub};
use crate::obs::now_us;
use crate::obs::trace::Trace;

/// Retention policy: errors, deadline misses and cancellations are
/// always kept; successes pass a deterministic per-id sampler and a
/// token-bucket rate limit.
#[derive(Debug, Clone, Copy)]
pub struct SamplePolicy {
    /// Probability a successful request's trace is retained (0..=1).
    /// Deterministic in the request id: the same id under the same seed
    /// always gets the same verdict.
    pub success_rate: f64,
    /// Seed for the sampling hash (fixed seed ⇒ reproducible keep-set).
    pub seed: u64,
    /// Sustained retained-successes per second (token bucket refill);
    /// 0 disables rate limiting.
    pub max_per_sec: f64,
    /// Token-bucket burst capacity.
    pub burst: f64,
}

impl Default for SamplePolicy {
    fn default() -> Self {
        // keep everything by default, but bound the sustained rate so a
        // saturating fleet can't spend its time churning the ring
        SamplePolicy { success_rate: 1.0, seed: 0x5eed_cafe, max_per_sec: 64.0, burst: 128.0 }
    }
}

/// splitmix64 over the id bytes — cheap, seed-keyed, stable across runs.
fn sample_hash(id: &str, seed: u64) -> u64 {
    let mut h = seed ^ 0x9e37_79b9_7f4a_7c15;
    for &b in id.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
        h ^= h >> 27;
        h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
        h ^= h >> 31;
    }
    h
}

impl SamplePolicy {
    /// Deterministic success-sampling verdict for a request id.
    pub fn sample_success(&self, id: &str) -> bool {
        if self.success_rate >= 1.0 {
            return true;
        }
        if self.success_rate <= 0.0 {
            return false;
        }
        // top 53 bits → uniform in [0,1)
        let u = (sample_hash(id, self.seed) >> 11) as f64 / (1u64 << 53) as f64;
        u < self.success_rate
    }
}

/// Recorder construction knobs (the `--trace-capacity`/`--trace-sample`
/// surface, carried through `PoolOptions`).
#[derive(Debug, Clone, Copy)]
pub struct TraceOptions {
    /// Ring capacity in traces; 0 disables retention (rollups still run).
    pub capacity: usize,
    pub sample: SamplePolicy,
    /// Calibration observatory + adaptive-tau controller knobs. The hub
    /// lives in the recorder because the recorder already sees every
    /// finished request exactly once.
    pub calib: CalibOptions,
}

impl Default for TraceOptions {
    fn default() -> Self {
        TraceOptions {
            capacity: 256,
            sample: SamplePolicy::default(),
            calib: CalibOptions::default(),
        }
    }
}

#[derive(Default)]
struct Ring {
    traces: VecDeque<Arc<Trace>>,
    /// Token bucket for retained successes.
    bucket: f64,
    last_refill_us: u64,
    // -------- rollups (exact, accumulated before sampling) --------
    recorded: u64,
    retained: u64,
    dropped: u64,
    er_flops_saved: f64,
    er_beams_rejected: u64,
}

/// Cumulative recorder counters (feed `/metrics` and the benchmarks'
/// per-mode FLOPs-saved reporting).
#[derive(Debug, Clone, Copy, Default)]
pub struct RecorderTotals {
    /// Traces submitted (every completed request).
    pub recorded: u64,
    /// Traces currently admitted to the ring (before eviction).
    pub retained: u64,
    /// Traces not retained: sampled out, rate-limited, or evicted.
    pub dropped: u64,
    /// Estimated FLOPs early rejection saved, summed over all requests.
    pub er_flops_saved: f64,
    /// Beams early-rejected, summed over all requests.
    pub er_beams_rejected: u64,
}

pub struct TraceRecorder {
    capacity: usize,
    policy: SamplePolicy,
    calib: CalibrationHub,
    inner: Mutex<Ring>,
}

impl TraceRecorder {
    pub fn new(opts: TraceOptions) -> TraceRecorder {
        let ring = Ring { bucket: opts.sample.burst, ..Ring::default() };
        TraceRecorder {
            capacity: opts.capacity,
            policy: opts.sample,
            calib: CalibrationHub::new(opts.calib),
            inner: Mutex::new(ring),
        }
    }

    pub fn policy(&self) -> &SamplePolicy {
        &self.policy
    }

    /// The calibration observatory fed by every submitted trace.
    pub fn calibration(&self) -> &CalibrationHub {
        &self.calib
    }

    /// Record a completed trace (rollups always; retention per policy).
    pub fn submit(&self, trace: Trace) {
        self.submit_at(trace, now_us());
    }

    /// `submit` with an explicit clock, so rate-limit behavior is
    /// testable without sleeping.
    pub fn submit_at(&self, trace: Trace, now_us: u64) {
        debug_assert!(trace.well_formed(), "submitted trace has open spans");
        // exact like the rollups below: folded before sampling
        self.calib.record(&trace.calib);
        let mut g = self.inner.lock().unwrap();
        g.recorded += 1;
        g.er_flops_saved += trace.er_flops_saved();
        g.er_beams_rejected += trace.er_rejected() as u64;

        let keep = self.capacity > 0 && self.admit(&mut g, &trace, now_us);
        if !keep {
            g.dropped += 1;
            return;
        }
        g.retained += 1;
        if g.traces.len() == self.capacity {
            g.traces.pop_front();
            g.dropped += 1; // evicted
        }
        g.traces.push_back(Arc::new(trace));
    }

    /// Sampling verdict: failures always kept, successes sampled then
    /// rate-limited.
    fn admit(&self, g: &mut Ring, trace: &Trace, now_us: u64) -> bool {
        // errors, deadline misses, cancellations: always retained
        if trace.status != 200 || trace.outcome != "ok" {
            return true;
        }
        if !self.policy.sample_success(&trace.id) {
            return false;
        }
        if self.policy.max_per_sec <= 0.0 {
            return true;
        }
        // refill, then spend one token per retained success
        let dt_s = now_us.saturating_sub(g.last_refill_us) as f64 / 1e6;
        g.last_refill_us = now_us;
        g.bucket = (g.bucket + dt_s * self.policy.max_per_sec).min(self.policy.burst);
        if g.bucket < 1.0 {
            return false;
        }
        g.bucket -= 1.0;
        true
    }

    /// Look a retained trace up by request id (newest match wins, in
    /// case a client reused an id).
    pub fn get(&self, id: &str) -> Option<Arc<Trace>> {
        let g = self.inner.lock().unwrap();
        g.traces.iter().rev().find(|t| t.id == id).cloned()
    }

    /// Newest-first summaries for `/traces`.
    pub fn recent(&self, n: usize) -> Vec<Arc<Trace>> {
        let g = self.inner.lock().unwrap();
        g.traces.iter().rev().take(n).cloned().collect()
    }

    /// Every retained trace, oldest first (the Chrome export input).
    pub fn all(&self) -> Vec<Arc<Trace>> {
        let g = self.inner.lock().unwrap();
        g.traces.iter().cloned().collect()
    }

    pub fn totals(&self) -> RecorderTotals {
        let g = self.inner.lock().unwrap();
        RecorderTotals {
            recorded: g.recorded,
            retained: g.retained,
            dropped: g.dropped,
            er_flops_saved: g.er_flops_saved,
            er_beams_rejected: g.er_beams_rejected,
        }
    }

    /// The recorder's `/metrics` rollups, exposition-format complete.
    pub fn render_metrics(&self) -> String {
        use crate::obs::metrics::MetricWriter;
        let t = self.totals();
        let mut w = MetricWriter::new();
        w.counter(
            "erprm_er_flops_saved_total",
            "Estimated FLOPs saved by early beam rejection (trace ledger).",
            t.er_flops_saved,
        );
        w.counter(
            "erprm_er_beams_rejected_total",
            "Beams early-rejected across all requests.",
            t.er_beams_rejected as f64,
        );
        w.counter(
            "erprm_traces_recorded_total",
            "Request traces submitted to the recorder.",
            t.recorded as f64,
        );
        w.counter(
            "erprm_trace_dropped_total",
            "Request traces not retained (sampled out, rate-limited, or evicted).",
            t.dropped as f64,
        );
        let mut out = w.finish();
        out.push_str(&self.calib.render_metrics());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{ErEvent, PhaseFlops, TraceBuilder};

    fn ok_trace(id: &str) -> Trace {
        TraceBuilder::start(id).finish("ok", 200, PhaseFlops::default())
    }

    fn no_limit(capacity: usize, rate: f64, seed: u64) -> TraceRecorder {
        TraceRecorder::new(TraceOptions {
            capacity,
            sample: SamplePolicy { success_rate: rate, seed, max_per_sec: 0.0, burst: 0.0 },
            calib: CalibOptions::default(),
        })
    }

    #[test]
    fn ring_evicts_oldest_under_overflow() {
        let r = no_limit(4, 1.0, 1);
        for i in 0..10 {
            r.submit(ok_trace(&format!("r{i}")));
        }
        let t = r.totals();
        assert_eq!(t.recorded, 10);
        assert_eq!(t.retained, 10);
        assert_eq!(t.dropped, 6); // evictions
        let recent = r.recent(100);
        assert_eq!(recent.len(), 4);
        assert_eq!(recent[0].id, "r9"); // newest first
        assert_eq!(recent[3].id, "r6");
        assert!(r.get("r0").is_none(), "evicted traces are gone");
        assert!(r.get("r9").is_some());
    }

    #[test]
    fn sampling_is_deterministic_under_a_fixed_seed() {
        let ids: Vec<String> = (0..200).map(|i| format!("req-{i:04}")).collect();
        let p = SamplePolicy { success_rate: 0.3, seed: 42, max_per_sec: 0.0, burst: 0.0 };
        let first: Vec<bool> = ids.iter().map(|i| p.sample_success(i)).collect();
        let second: Vec<bool> = ids.iter().map(|i| p.sample_success(i)).collect();
        assert_eq!(first, second, "same seed must give the same keep-set");
        let kept = first.iter().filter(|&&k| k).count();
        assert!(kept > 20 && kept < 120, "rate 0.3 kept {kept}/200");
        // a different seed picks a different set
        let p2 = SamplePolicy { seed: 43, ..p };
        let third: Vec<bool> = ids.iter().map(|i| p2.sample_success(i)).collect();
        assert_ne!(first, third);
        // and the recorder applies the same verdicts
        let r = no_limit(1000, 0.3, 42);
        for id in &ids {
            r.submit(ok_trace(id));
        }
        assert_eq!(r.totals().retained, kept as u64);
    }

    #[test]
    fn failures_bypass_sampling_and_rate_limits() {
        let r = TraceRecorder::new(TraceOptions {
            capacity: 100,
            sample: SamplePolicy { success_rate: 0.0, seed: 7, max_per_sec: 1.0, burst: 1.0 },
            calib: CalibOptions::default(),
        });
        r.submit(ok_trace("s1")); // sampled out
        r.submit(TraceBuilder::start("e1").finish("error", 500, PhaseFlops::default()));
        r.submit(TraceBuilder::start("d1").finish("deadline", 504, PhaseFlops::default()));
        r.submit(TraceBuilder::start("c1").finish("cancelled", 200, PhaseFlops::default()));
        let t = r.totals();
        assert_eq!(t.retained, 3);
        assert_eq!(t.dropped, 1);
        assert!(r.get("e1").is_some());
        assert!(r.get("d1").is_some());
        assert!(r.get("c1").is_some(), "non-ok outcome kept even with status 200");
        assert!(r.get("s1").is_none());
    }

    #[test]
    fn token_bucket_rate_limits_successes() {
        let r = TraceRecorder::new(TraceOptions {
            capacity: 100,
            sample: SamplePolicy { success_rate: 1.0, seed: 7, max_per_sec: 10.0, burst: 2.0 },
            calib: CalibOptions::default(),
        });
        // burst of 2, then dry at t=0
        for i in 0..5 {
            r.submit_at(ok_trace(&format!("a{i}")), 0);
        }
        assert_eq!(r.totals().retained, 2);
        // 100ms later one token refilled (10/s)
        r.submit_at(ok_trace("b0"), 100_000);
        r.submit_at(ok_trace("b1"), 100_000);
        assert_eq!(r.totals().retained, 3);
        assert!(r.get("b0").is_some());
        assert!(r.get("b1").is_none());
    }

    #[test]
    fn rollups_count_sampled_out_traces() {
        let r = no_limit(100, 0.0, 1);
        let mut tb = TraceBuilder::start("x");
        tb.reject(ErEvent {
            depth: 0,
            tau: 8,
            rejected: vec![0, 1],
            scores: vec![0.1, 0.2],
            flops_saved: 5.0,
        });
        r.submit(tb.finish("ok", 200, PhaseFlops::default()));
        let t = r.totals();
        assert_eq!(t.retained, 0, "sampled out");
        assert_eq!(t.er_beams_rejected, 2, "rollups still exact");
        assert_eq!(t.er_flops_saved, 5.0);
        assert_eq!(t.dropped, 1);
    }

    #[test]
    fn calibration_folds_before_sampling() {
        // success_rate 0 drops every trace from the ring — the hub must
        // still see every sample, like the ER rollups
        let r = no_limit(100, 0.0, 1);
        for i in 0..5u32 {
            let mut tb = TraceBuilder::start(format!("c{i}"));
            let v = 0.3 + 0.1 * i as f32;
            tb.calib_sample("prm-large", 0, v, v);
            tb.calib_regret(2, 1);
            tb.calib_control(true, true);
            r.submit(tb.finish("ok", 200, PhaseFlops::default()));
        }
        assert_eq!(r.totals().retained, 0);
        let s = r.calibration().snapshot();
        assert_eq!(s.samples_total, 5);
        assert_eq!(s.shadow_requests, 5);
        assert_eq!(s.regret_checked, 10);
        assert_eq!(s.regret_beams, 5);
        assert_eq!(s.rows.len(), 1);
        assert!(s.rows[0].pearson > 0.999);
        // and the combined render stays exposition-valid
        crate::obs::metrics::check_exposition(&r.render_metrics()).unwrap();
        assert!(r.render_metrics().contains("erprm_calib_samples"));
    }

    #[test]
    fn zero_capacity_disables_retention() {
        let r = no_limit(0, 1.0, 1);
        r.submit(ok_trace("z"));
        assert_eq!(r.totals().retained, 0);
        assert_eq!(r.totals().recorded, 1);
        assert!(r.recent(10).is_empty());
    }
}
