//! Chrome `trace_event` export — renders retained traces as a
//! per-shard / per-slot timeline loadable in `chrome://tracing` or
//! Perfetto (`GET /traces/chrome`, `fleet_benchmark --trace-out`).
//!
//! Mapping: process id = engine shard (pid 0 is the router/door for
//! work that never reached a shard: cache hits, admission rejects),
//! thread id = fleet slot within the shard (tid 0 for door/queue work
//! recorded before placement). Spans become complete `"X"` events,
//! instant markers (rejections, cache hits) become `"i"` events.

use std::sync::Arc;

use crate::obs::trace::Trace;
use crate::util::json::Json;

fn pid(t: &Trace) -> f64 {
    t.shard.map(|s| s as f64 + 1.0).unwrap_or(0.0)
}

fn tid(t: &Trace) -> f64 {
    t.slot.map(|s| s as f64 + 1.0).unwrap_or(0.0)
}

fn args(t: &Trace, detail: &str) -> Json {
    let mut pairs = vec![("request_id", Json::str(&t.id))];
    if !detail.is_empty() {
        pairs.push(("detail", Json::str(detail)));
    }
    Json::obj(pairs)
}

/// Render traces (oldest first) into one Chrome trace JSON document.
pub fn chrome_trace(traces: &[Arc<Trace>]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    // name the rows once per (pid, tid) pair seen
    let mut rows: Vec<(f64, f64)> = Vec::new();
    for t in traces {
        let (p, d) = (pid(t), tid(t));
        if !rows.contains(&(p, d)) {
            rows.push((p, d));
        }
        for s in &t.spans {
            events.push(Json::obj(vec![
                ("name", Json::str(s.name)),
                ("ph", Json::str("X")),
                ("ts", Json::num(s.start_us as f64)),
                ("dur", Json::num(s.dur_us.max(1) as f64)),
                ("pid", Json::num(p)),
                ("tid", Json::num(d)),
                ("args", args(t, &s.detail)),
            ]));
        }
        for e in &t.events {
            events.push(Json::obj(vec![
                ("name", Json::str(e.name)),
                ("ph", Json::str("i")),
                ("s", Json::str("t")),
                ("ts", Json::num(e.ts_us as f64)),
                ("pid", Json::num(p)),
                ("tid", Json::num(d)),
                ("args", args(t, &e.detail)),
            ]));
        }
    }
    let mut meta: Vec<Json> = Vec::new();
    for &(p, d) in &rows {
        let pname = if p == 0.0 { "router".to_string() } else { format!("shard {}", p - 1.0) };
        meta.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(p)),
            ("args", Json::obj(vec![("name", Json::str(pname))])),
        ]));
        let tname = if d == 0.0 { "door".to_string() } else { format!("slot {}", d - 1.0) };
        meta.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(p)),
            ("tid", Json::num(d)),
            ("args", Json::obj(vec![("name", Json::str(tname))])),
        ]));
    }
    meta.extend(events);
    Json::obj(vec![
        ("traceEvents", Json::Arr(meta)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{PhaseFlops, TraceBuilder};

    fn traced(id: &str, shard: usize, slot: usize) -> Arc<Trace> {
        let mut tb = TraceBuilder::start(id);
        tb.set_placement(shard, slot);
        tb.begin("solve");
        tb.begin_detail("decode", "b8");
        tb.end();
        tb.event("reject", "depth=0 rejected=2");
        tb.end();
        Arc::new(tb.finish("ok", 200, PhaseFlops::default()))
    }

    #[test]
    fn export_parses_and_carries_rows_and_spans() {
        let traces = vec![traced("r0", 0, 1), traced("r1", 1, 0)];
        let doc = chrome_trace(&traces);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let Some(Json::Arr(evs)) = parsed.get("traceEvents") else {
            panic!("no traceEvents array")
        };
        // 2 rows x 2 metadata + 2 x (2 spans + 1 instant)
        let metas = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .count();
        let spans: Vec<_> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .collect();
        let instants = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("i"))
            .count();
        assert_eq!(metas, 4);
        assert_eq!(spans.len(), 4);
        assert_eq!(instants, 2);
        for s in &spans {
            assert!(s.get("dur").and_then(Json::as_f64).unwrap() >= 1.0);
            assert!(s.get("args").and_then(|a| a.get("request_id")).is_some());
        }
        // shard 0 → pid 1, slot 1 → tid 2
        assert!(evs.iter().any(|e| {
            e.get("pid").and_then(Json::as_f64) == Some(1.0)
                && e.get("tid").and_then(Json::as_f64) == Some(2.0)
        }));
    }

    #[test]
    fn doorwork_lands_on_pid_zero() {
        let t = Arc::new(TraceBuilder::start("d").finish("cache_hit", 200, PhaseFlops::default()));
        let doc = chrome_trace(&[t]);
        let s = doc.to_string();
        assert!(s.contains("\"router\""));
        assert!(s.contains("\"door\""));
    }
}
