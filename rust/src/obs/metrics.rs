//! Prometheus exposition-format writer.
//!
//! Every `/metrics` renderer in the crate (`server::metrics`, the pool
//! gauges in `server::router`, the trace recorder rollups) goes through
//! [`MetricWriter`] so each `erprm_*` series carries its `# HELP` /
//! `# TYPE` header exactly once — including labelled families, where
//! the header precedes the first sample only. [`check_exposition`] is
//! the validity oracle the golden test pins the full render against.

use std::collections::HashSet;
use std::fmt::Write as _;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
}

impl MetricKind {
    fn name(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
        }
    }
}

/// Accumulates exposition text; emits the HELP/TYPE header the first
/// time each series name is written.
#[derive(Default)]
pub struct MetricWriter {
    out: String,
    seen: HashSet<String>,
}

/// Float formatting matching the crate's historical `/metrics` output:
/// integral values render without a fraction, others with enough
/// precision to round-trip.
fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6}")
    }
}

impl MetricWriter {
    pub fn new() -> MetricWriter {
        MetricWriter::default()
    }

    /// Core emitter: `labels` is the rendered label set without braces
    /// (e.g. `shard="0"`), empty for unlabelled series.
    pub fn write(
        &mut self,
        name: &str,
        kind: MetricKind,
        help: &str,
        labels: &str,
        value: impl std::fmt::Display,
    ) {
        if self.seen.insert(name.to_string()) {
            let _ = writeln!(self.out, "# HELP {name} {help}");
            let _ = writeln!(self.out, "# TYPE {name} {}", kind.name());
        }
        if labels.is_empty() {
            let _ = writeln!(self.out, "{name} {value}");
        } else {
            let _ = writeln!(self.out, "{name}{{{labels}}} {value}");
        }
    }

    pub fn counter(&mut self, name: &str, help: &str, v: f64) {
        self.write(name, MetricKind::Counter, help, "", fmt_value(v));
    }

    pub fn gauge(&mut self, name: &str, help: &str, v: f64) {
        self.write(name, MetricKind::Gauge, help, "", fmt_value(v));
    }

    pub fn counter_labeled(&mut self, name: &str, help: &str, labels: &str, v: f64) {
        self.write(name, MetricKind::Counter, help, labels, fmt_value(v));
    }

    pub fn gauge_labeled(&mut self, name: &str, help: &str, labels: &str, v: f64) {
        self.write(name, MetricKind::Gauge, help, labels, fmt_value(v));
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Validate Prometheus text exposition format (the subset this crate
/// emits): every sample's series carries `# HELP` and `# TYPE` headers
/// before its first sample, types are legal, headers aren't duplicated,
/// and every sample line parses as `name[{labels}] value`.
pub fn check_exposition(text: &str) -> Result<(), String> {
    let mut helped: HashSet<&str> = HashSet::new();
    let mut typed: HashSet<&str> = HashSet::new();
    let mut sampled: HashSet<&str> = HashSet::new();
    for (ln, line) in text.lines().enumerate() {
        let ln = ln + 1;
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().ok_or(format!("line {ln}: empty HELP"))?;
            if !helped.insert(name) {
                return Err(format!("line {ln}: duplicate # HELP for {name}"));
            }
            if sampled.contains(name) {
                return Err(format!("line {ln}: # HELP for {name} after its samples"));
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().ok_or(format!("line {ln}: empty TYPE"))?;
            let kind = it.next().ok_or(format!("line {ln}: TYPE {name} missing a type"))?;
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                return Err(format!("line {ln}: illegal type '{kind}' for {name}"));
            }
            if !typed.insert(name) {
                return Err(format!("line {ln}: duplicate # TYPE for {name}"));
            }
            if sampled.contains(name) {
                return Err(format!("line {ln}: # TYPE for {name} after its samples"));
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // free-form comment
        }
        // sample line: name[{labels}] value
        let name_end = line
            .find(|c: char| c == '{' || c.is_whitespace())
            .ok_or(format!("line {ln}: no value on sample line '{line}'"))?;
        let name = &line[..name_end];
        if name.is_empty()
            || !name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
        {
            return Err(format!("line {ln}: bad metric name '{name}'"));
        }
        let rest = &line[name_end..];
        let value_part = if let Some(r) = rest.strip_prefix('{') {
            let close = r.find('}').ok_or(format!("line {ln}: unclosed label set"))?;
            &r[close + 1..]
        } else {
            rest
        };
        let value = value_part.trim();
        if value.parse::<f64>().is_err() && !matches!(value, "NaN" | "+Inf" | "-Inf") {
            return Err(format!("line {ln}: unparseable value '{value}' for {name}"));
        }
        if !helped.contains(name) {
            return Err(format!("line {ln}: sample for {name} without # HELP"));
        }
        if !typed.contains(name) {
            return Err(format!("line {ln}: sample for {name} without # TYPE"));
        }
        sampled.insert(name);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headers_emitted_once_per_series() {
        let mut w = MetricWriter::new();
        w.counter("erprm_requests_total", "Requests.", 3.0);
        w.gauge_labeled("erprm_shard_depth", "Depth.", "shard=\"0\"", 1.0);
        w.gauge_labeled("erprm_shard_depth", "Depth.", "shard=\"1\"", 2.0);
        let text = w.finish();
        assert_eq!(text.matches("# TYPE erprm_shard_depth").count(), 1);
        assert_eq!(text.matches("# HELP erprm_shard_depth").count(), 1);
        assert!(text.contains("erprm_shard_depth{shard=\"0\"} 1"));
        assert!(text.contains("erprm_shard_depth{shard=\"1\"} 2"));
        check_exposition(&text).unwrap();
    }

    #[test]
    fn value_formatting_matches_historic_output() {
        let mut w = MetricWriter::new();
        w.counter("a_total", "A.", 12.0);
        w.gauge("b", "B.", 0.25);
        let text = w.finish();
        assert!(text.contains("a_total 12\n"), "{text}");
        assert!(text.contains("b 0.250000\n"), "{text}");
    }

    #[test]
    fn checker_rejects_missing_or_misplaced_headers() {
        assert!(check_exposition("erprm_x 1\n").is_err(), "sample without headers");
        assert!(check_exposition("# TYPE erprm_x gauge\nerprm_x 1\n").is_err(), "no HELP");
        assert!(check_exposition("# HELP erprm_x X.\nerprm_x 1\n").is_err(), "no TYPE");
        assert!(
            check_exposition("# HELP erprm_x X.\n# TYPE erprm_x bogus\nerprm_x 1\n").is_err(),
            "illegal type"
        );
        assert!(
            check_exposition(
                "# HELP erprm_x X.\n# TYPE erprm_x gauge\nerprm_x 1\n# TYPE erprm_x gauge\n"
            )
            .is_err(),
            "header after samples"
        );
        assert!(
            check_exposition("# HELP erprm_x X.\n# TYPE erprm_x gauge\nerprm_x oops\n").is_err(),
            "bad value"
        );
        let good = "# HELP erprm_x X.\n# TYPE erprm_x gauge\nerprm_x{shard=\"0\"} 1\nerprm_x{shard=\"1\"} 2.5\n";
        check_exposition(good).unwrap();
    }
}
