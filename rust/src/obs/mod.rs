//! Request-lifecycle observability: span/event tracing with per-request
//! FLOPs attribution.
//!
//! * [`trace`] — the lock-free [`TraceBuilder`] that rides inside a
//!   request (admission → queue → slot placement → decode/score ticks →
//!   early rejection → reply), the phase-split [`PhaseFlops`] ledger
//!   derived from the coordinator's `FlopsLedger` token counters, and
//!   the per-depth early-rejection ledger ([`ErEvent`]).
//! * [`recorder`] — the bounded [`TraceRecorder`] ring buffer behind
//!   `GET /trace/<id>` / `GET /traces`, with deterministic
//!   success-sampling + token-bucket retention and exact aggregate
//!   rollups (`erprm_er_flops_saved_total`, `erprm_trace_dropped_total`).
//! * [`chrome`] — Chrome `trace_event` export (`GET /traces/chrome`,
//!   `fleet_benchmark --trace-out`) rendering a fleet run as a
//!   per-shard / per-slot timeline in Perfetto.
//! * [`metrics`] — the Prometheus exposition writer every `/metrics`
//!   renderer shares, plus the format-validity checker the golden test
//!   pins.
//! * [`calibration`] — the online calibration observatory: streaming
//!   partial↔final reward correlation per (checkpoint, depth bucket)
//!   fed from every finished request, the confidence-gated evidence the
//!   adaptive-tau controller consumes, and the FLOPs-saved-vs-regret
//!   ledger (`GET /calibration`, `erprm_calib_*`).
//!
//! Requests are keyed by an id minted at the HTTP door (or accepted
//! from the client via an `X-Request-Id` header / `request_id` body
//! field) and echoed in the `/solve` response.

pub mod calibration;
pub mod chrome;
pub mod metrics;
pub mod recorder;
pub mod trace;

pub use calibration::{CalibOptions, CalibRow, CalibSnapshot, CalibrationHub};
pub use chrome::chrome_trace;
pub use metrics::{check_exposition, MetricKind, MetricWriter};
pub use recorder::{RecorderTotals, SamplePolicy, TraceOptions, TraceRecorder};
pub use trace::{CalibNote, ErEvent, PhaseFlops, Span, SpanEvent, Trace, TraceBuilder};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Process-wide monotonic epoch all trace timestamps are relative to,
/// so spans from different requests and shards share one timeline.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the trace epoch.
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Mint a process-unique request id: a per-process salt (wall clock at
/// first mint, so ids don't collide across restarts) plus a sequence
/// number.
pub fn mint_request_id() -> String {
    static SALT: OnceLock<u64> = OnceLock::new();
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let salt = *SALT.get_or_init(|| {
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0)
            & 0xffff_ffff
    });
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    format!("r{salt:08x}-{n:06}")
}

/// Validate a client-supplied request id: printable ASCII, sane length.
/// Returns `None` (caller mints instead) when unusable.
pub fn sanitize_request_id(id: &str) -> Option<String> {
    let id = id.trim();
    if id.is_empty() || id.len() > 128 {
        return None;
    }
    if !id.bytes().all(|b| (0x21..=0x7e).contains(&b)) {
        return None;
    }
    Some(id.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minted_ids_are_unique_and_sane() {
        let a = mint_request_id();
        let b = mint_request_id();
        assert_ne!(a, b);
        assert_eq!(sanitize_request_id(&a), Some(a));
    }

    #[test]
    fn sanitize_rejects_garbage() {
        assert_eq!(sanitize_request_id(""), None);
        assert_eq!(sanitize_request_id("   "), None);
        assert_eq!(sanitize_request_id("has space"), None);
        assert_eq!(sanitize_request_id("ctl\x07char"), None);
        assert_eq!(sanitize_request_id(&"x".repeat(200)), None);
        assert_eq!(sanitize_request_id(" ok-id_42 "), Some("ok-id_42".into()));
    }

    #[test]
    fn clock_is_monotonic() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
    }
}
