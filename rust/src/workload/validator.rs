//! Incremental token-level trace validator.
//!
//! Mirrors `grammar.ValidatorState` in Python exactly: feeds one token at a
//! time, `ok` flips to false at the first arithmetically or syntactically
//! wrong position — including a step that applies the wrong operation for
//! its index in the problem — and stays false (monotone "correct so far"
//! semantics, the quantity the PRM estimates). Used for answer checking,
//! oracle analyses, and the correlation studies' ground-truth labels.

use crate::tokenizer as tk;
use crate::workload::OpStep;

#[derive(Debug, Clone, PartialEq)]
enum Phase {
    Head,
    Scratch,
    Result,
    Answer,
}

#[derive(Debug, Clone)]
pub struct Validator {
    pub v: i64,
    pub ok: bool,
    pub done: bool,
    pub answer: Option<i64>,
    /// Expected (op, d) per step index; None disables problem checking
    /// (pure arithmetic-consistency mode).
    ops: Option<Vec<OpStep>>,
    step_idx: usize,
    phase: Phase,
    buf: Vec<i32>,
    step_op: i32,
    step_d: i64,
    items_seen: usize,
    expect: Vec<i64>,
    after_redundant: bool,
}

impl Validator {
    /// Validate against a problem: step k must apply the problem's k-th op.
    pub fn for_problem(p: &crate::workload::Problem) -> Self {
        let mut v = Validator::new(p.v0);
        v.ops = Some(p.ops.clone());
        v
    }

    /// Arithmetic-consistency-only mode (no expected op sequence).
    pub fn new(v0: i64) -> Self {
        Validator {
            v: v0,
            ok: true,
            done: false,
            answer: None,
            ops: None,
            step_idx: 0,
            phase: Phase::Head,
            buf: Vec::new(),
            step_op: 0,
            step_d: 0,
            items_seen: 0,
            expect: Vec::new(),
            after_redundant: false,
        }
    }

    fn fail(&mut self) {
        self.ok = false;
    }

    /// Consume one token; returns the current ok flag.
    pub fn feed(&mut self, tok: i32) -> bool {
        if self.done || !self.ok {
            if !self.done && tok == tk::EOS {
                self.done = true;
            }
            return self.ok;
        }
        match self.phase {
            Phase::Head => self.feed_head(tok),
            Phase::Scratch => self.feed_scratch(tok),
            Phase::Result => self.feed_result(tok),
            Phase::Answer => self.feed_answer(tok),
        }
        self.ok
    }

    /// Feed a whole slice, returning per-position labels.
    pub fn labels(&mut self, toks: &[i32]) -> Vec<bool> {
        toks.iter().map(|&t| self.feed(t)).collect()
    }

    fn feed_head(&mut self, tok: i32) {
        if tok == tk::ANS && self.buf.is_empty() {
            if let Some(ops) = &self.ops {
                if self.step_idx != ops.len() {
                    self.fail(); // answered before finishing all steps
                }
            }
            self.phase = Phase::Answer;
            self.buf.clear();
            return;
        }
        self.buf.push(tok);
        match self.buf.len() {
            1 | 2 => {
                if !tk::is_digit(tok) {
                    self.fail();
                } else if self.buf.len() == 2 {
                    let head_v = tk::parse_two_digits(self.buf[0], self.buf[1]).unwrap();
                    if head_v != self.v {
                        self.fail();
                    }
                }
            }
            3 => {
                if !tk::is_op(tok) {
                    self.fail();
                } else {
                    if let Some(ops) = &self.ops {
                        if self.step_idx >= ops.len() || tok != ops[self.step_idx].op {
                            self.fail(); // wrong operation for this step
                        }
                    }
                    self.step_op = tok;
                }
            }
            4 => {
                if !tk::is_digit(tok) {
                    self.fail();
                } else {
                    self.step_d = (tok - tk::DIG0) as i64;
                    if self.step_d < 1 {
                        self.fail();
                    } else if let Some(ops) = &self.ops {
                        if self.step_idx < ops.len() && self.step_d != ops[self.step_idx].d {
                            self.fail(); // wrong operand for this step
                        }
                    }
                }
            }
            5 => {
                if tok != tk::COLON {
                    self.fail();
                } else {
                    self.expect = tk::scratch_items(self.v, self.step_op, self.step_d);
                    self.items_seen = 0;
                    self.buf.clear();
                    self.after_redundant = false;
                    self.phase = Phase::Scratch;
                }
            }
            _ => self.fail(),
        }
    }

    fn feed_scratch(&mut self, tok: i32) {
        if tok == tk::FILL {
            if !self.buf.is_empty() {
                self.fail();
            } else if self.items_seen >= 2 {
                self.after_redundant = true;
            }
            return;
        }
        if tok == tk::EQ {
            if !self.buf.is_empty()
                || (self.items_seen < self.expect.len() && !self.after_redundant)
            {
                self.fail();
            } else {
                self.buf.clear();
                self.phase = Phase::Result;
            }
            return;
        }
        if tk::is_digit(tok) {
            self.buf.push(tok);
            if self.buf.len() > 2 {
                self.fail();
            }
            return;
        }
        if tok == tk::SPACE {
            if self.buf.len() != 2 {
                self.fail();
                return;
            }
            let val = tk::parse_two_digits(self.buf[0], self.buf[1]).unwrap();
            self.buf.clear();
            if self.after_redundant {
                let tail_start = self.expect.len().saturating_sub(2);
                if !self.expect[tail_start..].contains(&val) {
                    self.fail();
                }
            } else if self.items_seen >= self.expect.len() || val != self.expect[self.items_seen]
            {
                self.fail();
            } else {
                self.items_seen += 1;
            }
            return;
        }
        self.fail();
    }

    fn feed_result(&mut self, tok: i32) {
        self.buf.push(tok);
        match self.buf.len() {
            1 | 2 => {
                if !tk::is_digit(tok) {
                    self.fail();
                }
            }
            3 => {
                if tok != tk::SEMI {
                    self.fail();
                } else {
                    let val = tk::parse_two_digits(self.buf[0], self.buf[1]).unwrap();
                    let want = tk::apply_op(self.v, self.step_op, self.step_d);
                    if val != want {
                        self.fail();
                    } else {
                        self.v = want;
                        self.step_idx += 1;
                        self.buf.clear();
                        self.phase = Phase::Head;
                    }
                }
            }
            _ => self.fail(),
        }
    }

    fn feed_answer(&mut self, tok: i32) {
        self.buf.push(tok);
        match self.buf.len() {
            1 | 2 => {
                if !tk::is_digit(tok) {
                    self.fail();
                }
            }
            3 => {
                if tok != tk::EOS {
                    self.fail();
                } else {
                    let val = tk::parse_two_digits(self.buf[0], self.buf[1]).unwrap();
                    self.answer = Some(val);
                    if val != self.v {
                        self.fail();
                    }
                    self.done = true;
                }
            }
            _ => self.fail(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::{gen_problem, ALL_BENCHMARKS};

    #[test]
    fn gold_traces_validate() {
        let mut rng = Rng::new(0);
        for spec in &ALL_BENCHMARKS {
            for _ in 0..100 {
                let p = gen_problem(&mut rng, spec);
                let mut v = Validator::for_problem(&p);
                let labels = v.labels(&p.gold_solution());
                assert!(labels.iter().all(|&l| l), "{}", tk::detok(&p.gold_solution()));
                assert!(v.done);
                assert_eq!(v.answer, Some(p.answer()));
            }
        }
    }

    #[test]
    fn wrong_op_step_detected() {
        // problem says *6 but the trace does +6 (internally consistent):
        // arithmetic-only mode accepts it; problem mode must reject at the
        // op token — this is the LM's dominant real failure mode.
        let p = crate::workload::Problem {
            v0: 12,
            ops: vec![crate::workload::OpStep { op: tk::TIMES, d: 6 }],
        };
        let wrong = crate::workload::Problem {
            v0: 12,
            ops: vec![crate::workload::OpStep { op: tk::PLUS, d: 6 }],
        };
        let trace = wrong.gold_solution();
        assert!(Validator::new(p.v0).labels(&trace).iter().all(|&l| l));
        let labels = Validator::for_problem(&p).labels(&trace);
        assert!(!labels.iter().all(|&l| l));
        // failure exactly at the op token (index 2: v v op)
        assert!(labels[0] && labels[1] && !labels[2]);
    }

    #[test]
    fn early_answer_detected() {
        // answering after 1 of 2 steps with a consistent running value
        let p = crate::workload::Problem {
            v0: 10,
            ops: vec![
                crate::workload::OpStep { op: tk::PLUS, d: 2 },
                crate::workload::OpStep { op: tk::PLUS, d: 3 },
            ],
        };
        let one = crate::workload::Problem { v0: 10, ops: vec![p.ops[0]] };
        let trace = one.gold_solution();
        let mut v = Validator::for_problem(&p);
        let labels = v.labels(&trace);
        assert!(!labels.iter().all(|&l| l));
    }

    #[test]
    fn wrong_head_value_fails() {
        let mut v = Validator::new(12);
        for t in tk::two_digits(99) {
            v.feed(t);
        }
        assert!(!v.ok);
    }

    #[test]
    fn wrong_scratch_item_fails_at_that_item() {
        // 12+2:13 14 =14;  -> corrupt first item to 19
        let mut toks = Vec::new();
        toks.extend(tk::two_digits(12));
        toks.extend([tk::PLUS, tk::DIG0 + 2, tk::COLON]);
        toks.extend(tk::two_digits(19)); // wrong (should be 13)
        toks.push(tk::SPACE);
        let mut v = Validator::new(12);
        let labels = v.labels(&toks);
        assert!(labels[..labels.len() - 1].iter().all(|&l| l));
        assert!(!labels[labels.len() - 1]);
    }

    #[test]
    fn wrong_result_fails() {
        let mut toks = Vec::new();
        toks.extend(tk::two_digits(12));
        toks.extend([tk::PLUS, tk::DIG0 + 2, tk::COLON]);
        for item in [13, 14] {
            toks.extend(tk::two_digits(item));
            toks.push(tk::SPACE);
        }
        toks.push(tk::EQ);
        toks.extend(tk::two_digits(15)); // wrong: should be 14
        toks.push(tk::SEMI);
        let mut v = Validator::new(12);
        let labels = v.labels(&toks);
        assert!(!labels[labels.len() - 1]);
    }

    #[test]
    fn wrong_answer_fails_and_records() {
        let p = crate::workload::Problem {
            v0: 12,
            ops: vec![crate::workload::OpStep { op: tk::PLUS, d: 2 }],
        };
        let mut sol = p.gold_solution();
        let n = sol.len();
        sol[n - 2] = tk::DIG0 + (((sol[n - 2] - tk::DIG0) + 1) % 10);
        let mut v = Validator::new(p.v0);
        v.labels(&sol);
        assert!(!v.ok);
        assert!(v.done);
        assert_ne!(v.answer, Some(p.answer()));
    }

    #[test]
    fn monotone_once_failed() {
        let mut v = Validator::new(0);
        v.feed(tk::EOS); // malformed start? EOS in head phase -> fail path
        let ok_after = v.feed(tk::DIG0);
        assert!(!ok_after || v.done);
        // explicit: corrupt then feed valid tokens, must stay failed
        let mut v2 = Validator::new(12);
        for t in tk::two_digits(99) {
            v2.feed(t);
        }
        assert!(!v2.ok);
        for t in tk::two_digits(12) {
            assert!(!v2.feed(t));
        }
    }

    #[test]
    fn verbose_filler_and_redundancy_accepted() {
        // 12+3:~~13 14 15 ~14 15 =15;
        let mut toks = Vec::new();
        toks.extend(tk::two_digits(12));
        toks.extend([tk::PLUS, tk::DIG0 + 3, tk::COLON, tk::FILL, tk::FILL]);
        for item in [13, 14, 15] {
            toks.extend(tk::two_digits(item));
            toks.push(tk::SPACE);
        }
        toks.push(tk::FILL);
        for item in [14, 15] {
            toks.extend(tk::two_digits(item));
            toks.push(tk::SPACE);
        }
        toks.push(tk::EQ);
        toks.extend(tk::two_digits(15));
        toks.push(tk::SEMI);
        let mut v = Validator::new(12);
        let labels = v.labels(&toks);
        assert!(labels.iter().all(|&l| l), "{}", tk::detok(&toks));
        assert_eq!(v.v, 15);
    }
}
