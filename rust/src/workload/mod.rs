//! Benchmark workload generators + answer checking.
//!
//! Synthetic analogs of the paper's three math benchmarks (DESIGN.md
//! "Substitutions"): a difficulty gradient of arithmetic-chain problems
//! with mechanically checkable answers. Mirrors `python/compile/grammar.py`
//! exactly (tested against the same fixtures).

pub mod validator;

use crate::tokenizer as tk;
use crate::util::rng::Rng;

/// One chained operation of a problem.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpStep {
    pub op: i32, // PLUS | MINUS | TIMES token
    pub d: i64,  // operand, 2..=9
}

/// A benchmark problem: start value + K chained operations.
#[derive(Debug, Clone, PartialEq)]
pub struct Problem {
    pub v0: i64,
    pub ops: Vec<OpStep>,
}

impl Problem {
    pub fn answer(&self) -> i64 {
        self.ops.iter().fold(self.v0, |v, s| tk::apply_op(v, s.op, s.d))
    }

    /// Prompt token encoding: BOS v0 (op d ';')*K '>'.
    ///
    /// Ops are ';'-separated so the k-th op follows the (k-1)-th ';' —
    /// aligned with the ';' count of the solution so far, which makes op
    /// retrieval a countable attention pattern for the small LM (see
    /// grammar.py).
    pub fn prompt_tokens(&self) -> Vec<i32> {
        let mut t = vec![tk::BOS];
        t.extend(tk::two_digits(self.v0));
        for s in &self.ops {
            t.push(s.op);
            t.push(tk::DIG0 + s.d as i32);
            t.push(tk::SEMI);
        }
        t.push(tk::SEP);
        t
    }

    /// Gold solution tokens (concise style) — reference traces for tests
    /// and for the oracle baseline.
    pub fn gold_solution(&self) -> Vec<i32> {
        let mut t = Vec::new();
        let mut v = self.v0;
        for s in &self.ops {
            t.extend(tk::two_digits(v));
            t.push(s.op);
            t.push(tk::DIG0 + s.d as i32);
            t.push(tk::COLON);
            for item in tk::scratch_items(v, s.op, s.d) {
                t.extend(tk::two_digits(item));
                t.push(tk::SPACE);
            }
            v = tk::apply_op(v, s.op, s.d);
            t.push(tk::EQ);
            t.extend(tk::two_digits(v));
            t.push(tk::SEMI);
        }
        t.push(tk::ANS);
        t.extend(tk::two_digits(v));
        t.push(tk::EOS);
        t
    }
}

/// Benchmark descriptor: mirrors grammar.BENCHMARKS.
#[derive(Debug, Clone, Copy)]
pub struct BenchSpec {
    pub name: &'static str,
    pub k: usize,
    pub d_lo: i64,
    pub d_hi: i64,
    pub p_times: f64,
}

pub const SATMATH: BenchSpec = BenchSpec { name: "satmath-s", k: 3, d_lo: 2, d_hi: 6, p_times: 0.2 };
pub const MATH500: BenchSpec = BenchSpec { name: "math500-s", k: 4, d_lo: 2, d_hi: 8, p_times: 0.35 };
pub const AIME: BenchSpec = BenchSpec { name: "aime-s", k: 5, d_lo: 4, d_hi: 9, p_times: 0.5 };

pub const ALL_BENCHMARKS: [BenchSpec; 3] = [SATMATH, MATH500, AIME];

pub fn bench_by_name(name: &str) -> Option<BenchSpec> {
    ALL_BENCHMARKS.iter().copied().find(|b| b.name == name)
}

/// Generate one problem from a benchmark spec.
pub fn gen_problem(rng: &mut Rng, spec: &BenchSpec) -> Problem {
    let mut ops = Vec::with_capacity(spec.k);
    for _ in 0..spec.k {
        let r = rng.f64();
        let op = if r < spec.p_times {
            tk::TIMES
        } else if r < (1.0 + spec.p_times) / 2.0 {
            tk::PLUS
        } else {
            tk::MINUS
        };
        ops.push(OpStep { op, d: rng.range(spec.d_lo, spec.d_hi) });
    }
    Problem { v0: rng.range(0, tk::MOD - 1), ops }
}

/// A deterministic problem set for an experiment cell (seeded).
pub fn problem_set(spec: &BenchSpec, n: usize, seed: u64) -> Vec<Problem> {
    let mut rng = Rng::new(seed ^ 0xBE9C4A11);
    (0..n).map(|_| gen_problem(&mut rng, spec)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn problem_answer_chain() {
        let p = Problem {
            v0: 10,
            ops: vec![
                OpStep { op: tk::PLUS, d: 5 },
                OpStep { op: tk::TIMES, d: 3 },
                OpStep { op: tk::MINUS, d: 9 },
            ],
        };
        assert_eq!(p.answer(), ((10 + 5) * 3 - 9) % 100);
    }

    #[test]
    fn prompt_encoding() {
        let p = Problem { v0: 61, ops: vec![OpStep { op: tk::MINUS, d: 5 }] };
        assert_eq!(tk::detok(&p.prompt_tokens()), "<bos>61-5;>");
    }

    #[test]
    fn gold_solution_matches_python_fixture() {
        // fixture from python: Problem(61, [(-,5),(*,6),(+,4)])
        let p = Problem {
            v0: 61,
            ops: vec![
                OpStep { op: tk::MINUS, d: 5 },
                OpStep { op: tk::TIMES, d: 6 },
                OpStep { op: tk::PLUS, d: 4 },
            ],
        };
        let s = tk::detok(&p.gold_solution());
        assert_eq!(
            s,
            "61-5:60 59 58 57 56 =56;56*6:56 12 68 24 80 36 =36;36+4:37 38 39 40 =40;A40<eos>"
        );
    }

    #[test]
    fn gold_solution_answer_extractable() {
        let mut rng = Rng::new(4);
        for spec in &ALL_BENCHMARKS {
            for _ in 0..50 {
                let p = gen_problem(&mut rng, spec);
                assert_eq!(tk::extract_answer(&p.gold_solution()), Some(p.answer()));
            }
        }
    }

    #[test]
    fn benchmark_specs_are_graded() {
        assert!(SATMATH.k < MATH500.k && MATH500.k < AIME.k);
        assert!(SATMATH.p_times < AIME.p_times);
    }

    #[test]
    fn problem_sets_deterministic() {
        let a = problem_set(&SATMATH, 10, 42);
        let b = problem_set(&SATMATH, 10, 42);
        assert_eq!(a, b);
        let c = problem_set(&SATMATH, 10, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn prompts_fit_prompt_pad() {
        let mut rng = Rng::new(9);
        for spec in &ALL_BENCHMARKS {
            for _ in 0..100 {
                let p = gen_problem(&mut rng, spec);
                assert!(p.prompt_tokens().len() <= 24);
            }
        }
    }
}
