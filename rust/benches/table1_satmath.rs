//! Paper Table 1: SAT-MATH grid — accuracy + total FLOPs for every
//! (LM, PRM) combo under vanilla decoding and ER(tau) across beam widths.

mod common;

use erprm::config::SearchMode;
use erprm::harness::{run_cell, Cell};
use erprm::util::benchkit::{fmt_flops, Table};
use erprm::workload::SATMATH;

fn main() {
    let Some(engine) = common::engine() else { return };
    let problems = common::problems(12);
    let seed = 42;

    for lm in ["lm-concise", "lm-verbose"] {
        for prm in ["prm-large", "prm-small"] {
            let mut table = Table::new(
                &format!(
                    "Table 1 (satmath-s) — {lm} + {prm}, {problems} problems/cell"
                ),
                &["setting", "N", "accuracy %", "total FLOPs", "x vs vanilla"],
            );
            for n in common::n_grid() {
                let mut settings = vec![(SearchMode::Vanilla, 1usize, "vanilla".to_string())];
                for tau in common::tau_grid() {
                    settings.push((SearchMode::EarlyRejection, tau, format!("ER(tau={tau})")));
                }
                let mut base_flops = None;
                for (mode, tau, label) in settings {
                    let cell = Cell {
                        bench: SATMATH,
                        lm_ckpt: lm.into(),
                        prm_ckpt: prm.into(),
                        mode,
                        n_beams: n,
                        tau,
                    };
                    match run_cell(&engine, &cell, problems, seed) {
                        Ok(res) => {
                            let total = res.ledger.total_flops();
                            if mode == SearchMode::Vanilla {
                                base_flops = Some(total);
                            }
                            let reduction = base_flops
                                .map(|b| format!("{:.2}x", b / total))
                                .unwrap_or_else(|| "-".into());
                            table.row(vec![
                                label,
                                n.to_string(),
                                format!("{:.1}", res.accuracy),
                                fmt_flops(total),
                                reduction,
                            ]);
                        }
                        Err(e) => eprintln!("cell failed: {e}"),
                    }
                }
            }
            table.emit(&format!("table1_{lm}_{prm}"));
        }
    }
}
