//! Paper Table 2: Math-500 + AIME grids with the MathShepherd-analog PRM
//! (prm-large), both LMs, vanilla vs ER(tau).

mod common;

use erprm::config::SearchMode;
use erprm::harness::{run_cell, Cell};
use erprm::util::benchkit::{fmt_flops, Table};
use erprm::workload::{AIME, MATH500};

fn main() {
    let Some(engine) = common::engine() else { return };
    let problems = common::problems(10);
    let seed = 43;

    for bench in [MATH500, AIME] {
        for lm in ["lm-concise", "lm-verbose"] {
            let mut table = Table::new(
                &format!("Table 2 ({}) — {lm} + prm-large, {problems} problems/cell", bench.name),
                &["setting", "N", "accuracy %", "total FLOPs", "x vs vanilla"],
            );
            for n in common::n_grid() {
                let mut base = None;
                let mut settings = vec![(SearchMode::Vanilla, 1usize, "vanilla".to_string())];
                for tau in common::tau_grid() {
                    settings.push((SearchMode::EarlyRejection, tau, format!("ER(tau={tau})")));
                }
                for (mode, tau, label) in settings {
                    let cell = Cell {
                        bench,
                        lm_ckpt: lm.into(),
                        prm_ckpt: "prm-large".into(),
                        mode,
                        n_beams: n,
                        tau,
                    };
                    match run_cell(&engine, &cell, problems, seed) {
                        Ok(res) => {
                            let total = res.ledger.total_flops();
                            if mode == SearchMode::Vanilla {
                                base = Some(total);
                            }
                            table.row(vec![
                                label,
                                n.to_string(),
                                format!("{:.1}", res.accuracy),
                                fmt_flops(total),
                                base.map(|b| format!("{:.2}x", b / total))
                                    .unwrap_or_else(|| "-".into()),
                            ]);
                        }
                        Err(e) => eprintln!("cell failed: {e}"),
                    }
                }
            }
            table.emit(&format!("table2_{}_{lm}", bench.name));
        }
    }
}
