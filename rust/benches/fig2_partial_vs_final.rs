//! Paper Fig. 2: partial rewards at half-step completion vs full rewards,
//! with a linear fit — per PRM. The paper reports R^2 = 0.72 for
//! MathShepherd-7B and 0.63 for the MetaMath PRM; the shape to reproduce
//! is a strong positive linear relationship for both evaluators.

mod common;

use erprm::harness::correlation::{half_vs_final_fit, score_corpus};
use erprm::util::benchkit::Table;
use erprm::workload::MATH500;

fn main() {
    let Some(engine) = common::engine() else { return };
    let n_traces = common::problems(64).max(32);

    for prm in ["prm-large", "prm-small"] {
        let traces = match score_corpus(&engine, prm, &MATH500, n_traces, 2024) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("corpus failed: {e}");
                return;
            }
        };
        let (fit, pts) = half_vs_final_fit(&traces);
        let mut table = Table::new(
            &format!("Fig. 2 — {prm}: final = a + b * partial(half), {n_traces} traces"),
            &["quantity", "value"],
        );
        table.row(vec!["slope".into(), format!("{:.3}", fit.slope)]);
        table.row(vec!["intercept".into(), format!("{:.3}", fit.intercept)]);
        table.row(vec!["R^2".into(), format!("{:.3}", fit.r2)]);
        table.row(vec!["paper R^2 (MathShepherd-7B)".into(), "0.72".into()]);
        table.row(vec!["paper R^2 (MetaMath-7B)".into(), "0.63".into()]);
        table.emit(&format!("fig2_{prm}"));

        // scatter series (the figure's points), binned for terminal output
        let mut scatter = Table::new(
            &format!("Fig. 2 scatter ({prm}) — partial(half) bin -> mean final"),
            &["partial bin", "mean final", "count"],
        );
        let mut bins = vec![(0.0f64, 0usize); 10];
        for &(x, y) in &pts {
            let b = ((x * 10.0) as usize).min(9);
            bins[b].0 += y;
            bins[b].1 += 1;
        }
        for (i, (sum, cnt)) in bins.iter().enumerate() {
            if *cnt > 0 {
                scatter.row(vec![
                    format!("{:.1}-{:.1}", i as f64 / 10.0, (i + 1) as f64 / 10.0),
                    format!("{:.3}", sum / *cnt as f64),
                    cnt.to_string(),
                ]);
            }
        }
        scatter.emit(&format!("fig2_scatter_{prm}"));
    }
}
