//! Shared plumbing for the paper-table benches.
//!
//! Every bench prints the same rows/series its paper artifact reports and
//! tees them under target/bench-out/. Problem counts and beam grids scale
//! with ERPRM_PROBLEMS / ERPRM_FULL to keep `cargo bench` tractable on the
//! single-core testbed (the table *shape* is stable across scales).

use std::path::{Path, PathBuf};

use erprm::runtime::Engine;

pub fn artifacts() -> Option<PathBuf> {
    for c in [Path::new("artifacts"), Path::new("../artifacts")] {
        if c.join("manifest.json").exists() {
            return Some(c.to_path_buf());
        }
    }
    eprintln!("[bench] artifacts missing; run `make artifacts` first");
    None
}

pub fn engine() -> Option<Engine> {
    artifacts().map(|d| Engine::load(&d).expect("engine load"))
}

/// Beam-width grid: paper uses {4,8,16,32,64}; the default bench run covers
/// {4,8,16} (set ERPRM_FULL=1 for the paper's full grid).
pub fn n_grid() -> Vec<usize> {
    if std::env::var("ERPRM_FULL").is_ok() {
        vec![4, 8, 16, 32, 64]
    } else {
        vec![4, 8, 16]
    }
}

/// tau grid (scaled from the paper's {32,64,128} over ~300-token steps to
/// the same tau/L ratios over our 15-46-token steps).
pub fn tau_grid() -> Vec<usize> {
    vec![4, 8, 16]
}

pub fn problems(default: usize) -> usize {
    erprm::harness::problems_per_cell(default)
}
