//! Paper Fig. 5: SAT-MATH accuracy-vs-FLOPs series for ER vs vanilla
//! across the two LLMs and two PRMs (the figure's four panels as series).

mod common;

use erprm::config::SearchMode;
use erprm::harness::{run_cell, Cell};
use erprm::util::benchkit::{fmt_flops, Table};
use erprm::workload::SATMATH;

fn main() {
    let Some(engine) = common::engine() else { return };
    let problems = common::problems(10);
    let tau = 8;

    for lm in ["lm-concise", "lm-verbose"] {
        for prm in ["prm-large", "prm-small"] {
            let mut table = Table::new(
                &format!("Fig. 5 panel — {lm} + {prm} (satmath-s, tau={tau})"),
                &["series", "N", "FLOPs (x)", "accuracy % (y)"],
            );
            for n in common::n_grid() {
                for (mode, label) in
                    [(SearchMode::Vanilla, "vanilla"), (SearchMode::EarlyRejection, "ER")]
                {
                    let cell = Cell {
                        bench: SATMATH,
                        lm_ckpt: lm.into(),
                        prm_ckpt: prm.into(),
                        mode,
                        n_beams: n,
                        tau,
                    };
                    match run_cell(&engine, &cell, problems, 45) {
                        Ok(res) => table.row(vec![
                            label.into(),
                            n.to_string(),
                            fmt_flops(res.ledger.total_flops()),
                            format!("{:.1}", res.accuracy),
                        ]),
                        Err(e) => eprintln!("cell failed: {e}"),
                    }
                }
            }
            table.emit(&format!("fig5_{lm}_{prm}"));
        }
    }
}
