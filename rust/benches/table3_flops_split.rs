//! Paper Table 3: total FLOPs split LLM vs PRM for each LM-PRM combination
//! under vanilla, ER(tau=8-analog of 32) and ER(tau=16-analog of 64).

mod common;

use erprm::config::SearchMode;
use erprm::harness::{run_cell, Cell};
use erprm::util::benchkit::{fmt_flops, Table};
use erprm::workload::SATMATH;

fn main() {
    let Some(engine) = common::engine() else { return };
    let problems = common::problems(10);
    let n = 16;
    let seed = 44;

    let mut table = Table::new(
        &format!("Table 3 — FLOPs split (satmath-s, N={n}, {problems} problems/cell)"),
        &["combo", "setting", "LM FLOPs", "PRM FLOPs", "total", "x vs vanilla"],
    );
    for (lm, lm_label) in [("lm-concise", "Llama-a"), ("lm-verbose", "Qwen-a")] {
        for (prm, prm_label) in [("prm-large", "Math"), ("prm-small", "Skywork")] {
            let combo = format!("{lm_label}+{prm_label}");
            let mut base = None;
            for (mode, tau, label) in [
                (SearchMode::Vanilla, 1usize, "vanilla"),
                (SearchMode::EarlyRejection, 8, "ER(tau=8)"),
                (SearchMode::EarlyRejection, 16, "ER(tau=16)"),
            ] {
                let cell = Cell {
                    bench: SATMATH,
                    lm_ckpt: lm.into(),
                    prm_ckpt: prm.into(),
                    mode,
                    n_beams: n,
                    tau,
                };
                match run_cell(&engine, &cell, problems, seed) {
                    Ok(res) => {
                        let r = res.ledger.report();
                        if mode == SearchMode::Vanilla {
                            base = Some(r.total_flops);
                        }
                        table.row(vec![
                            combo.clone(),
                            label.into(),
                            fmt_flops(r.lm_flops),
                            fmt_flops(r.prm_flops),
                            fmt_flops(r.total_flops),
                            base.map(|b| format!("{:.2}x", b / r.total_flops))
                                .unwrap_or_else(|| "-".into()),
                        ]);
                    }
                    Err(e) => eprintln!("cell failed: {e}"),
                }
            }
        }
    }
    table.emit("table3_flops_split");
}
