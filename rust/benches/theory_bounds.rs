//! Paper Sec. 4 reproduction: the sqrt(tau/L) correlation law and the
//! sub-Gaussian prune-the-optimal-beam bound, Monte-Carlo validated.

mod common;

use erprm::sim;
use erprm::util::benchkit::Table;

fn main() {
    let trials = 6000;

    let mut t1 = Table::new(
        "Sec. 4 — rho(P,F) = sqrt(tau/L) (toy model, L=64)",
        &["tau", "pearson (MC)", "kendall (MC)", "exact sqrt(tau/L)"],
    );
    for tau in [4usize, 8, 16, 24, 32, 48, 64] {
        let (p, k) = sim::toy_correlation(tau, 64, trials, 7);
        t1.row(vec![
            tau.to_string(),
            format!("{p:.3}"),
            format!("{k:.3}"),
            format!("{:.3}", sim::toy_correlation_exact(tau, 64)),
        ]);
    }
    t1.emit("theory_sqrt_law");

    let mut t2 = Table::new(
        "Sec. 4 — Pr[prune optimal] <= (N-1) exp(-Delta^2/4sigma^2)  (N=16, M=4)",
        &["tau", "delta/token", "empirical Pr", "bound", "holds"],
    );
    for &(tau, d) in &[
        (4usize, 0.25f64),
        (8, 0.25),
        (16, 0.25),
        (32, 0.25),
        (64, 0.25),
        (16, 0.1),
        (16, 0.5),
        (16, 1.0),
    ] {
        let (emp, bound) = sim::prune_probability(16, 4, tau, d, 1.0, trials, 11);
        t2.row(vec![
            tau.to_string(),
            format!("{d:.2}"),
            format!("{emp:.4}"),
            format!("{bound:.4}"),
            (emp <= bound + 0.02).to_string(),
        ]);
    }
    t2.emit("theory_prune_bound");

    let mut t3 = Table::new(
        "Sec. 4 — min tau for target correlation (tau >= rho*^2 L)",
        &["rho*", "L", "min tau"],
    );
    for &(rho, l) in &[(0.7f64, 100usize), (0.8, 100), (0.9, 100), (0.8, 32)] {
        t3.row(vec![
            format!("{rho:.1}"),
            l.to_string(),
            sim::min_tau_for_rho(rho, l).to_string(),
        ]);
    }
    t3.emit("theory_min_tau");
}
