//! Paper Fig. 6: Math-500 and AIME accuracy-vs-FLOPs series, ER vs vanilla,
//! with the MathShepherd-analog PRM.

mod common;

use erprm::config::SearchMode;
use erprm::harness::{run_cell, Cell};
use erprm::util::benchkit::{fmt_flops, Table};
use erprm::workload::{AIME, MATH500};

fn main() {
    let Some(engine) = common::engine() else { return };
    let problems = common::problems(8);
    let tau = 8;

    for bench in [MATH500, AIME] {
        for lm in ["lm-concise", "lm-verbose"] {
            let mut table = Table::new(
                &format!("Fig. 6 panel — {} / {lm} + prm-large (tau={tau})", bench.name),
                &["series", "N", "FLOPs (x)", "accuracy % (y)"],
            );
            for n in common::n_grid() {
                for (mode, label) in
                    [(SearchMode::Vanilla, "vanilla"), (SearchMode::EarlyRejection, "ER")]
                {
                    let cell = Cell {
                        bench,
                        lm_ckpt: lm.into(),
                        prm_ckpt: "prm-large".into(),
                        mode,
                        n_beams: n,
                        tau,
                    };
                    match run_cell(&engine, &cell, problems, 46) {
                        Ok(res) => table.row(vec![
                            label.into(),
                            n.to_string(),
                            fmt_flops(res.ledger.total_flops()),
                            format!("{:.1}", res.accuracy),
                        ]),
                        Err(e) => eprintln!("cell failed: {e}"),
                    }
                }
            }
            table.emit(&format!("fig6_{}_{lm}", bench.name));
        }
    }
}
