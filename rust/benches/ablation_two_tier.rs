//! Paper Sec. 3.2 ablation: two-tiered batching (shrink to b2 for the
//! completion phase) vs staying at b1 — same algorithm, same FLOPs ledger,
//! different wallclock (the paper's claim is a throughput effect).
//! Also ablates the rejection policy (paper's top-N/M vs extensions).

mod common;

use std::time::Instant;

use erprm::config::{SearchConfig, SearchMode};
use erprm::coordinator::early_reject::solve_early_rejection_with_policy;
use erprm::coordinator::policy::RejectPolicy;
use erprm::util::benchkit::{fmt_flops, Table};
use erprm::workload::{problem_set, SATMATH};

fn main() {
    let Some(engine) = common::engine() else { return };
    let problems = problem_set(&SATMATH, common::problems(8), 48);
    let n = 16;

    let mut table = Table::new(
        &format!("Ablation — two-tier batching & policy (satmath-s, N={n}, tau=8)"),
        &["variant", "accuracy %", "total FLOPs", "wall s", "throughput (prob/s)"],
    );

    // Best-of-N baseline row (no step-level selection at all)
    {
        let cfg = SearchConfig {
            mode: SearchMode::EarlyRejection,
            n_beams: n,
            tau: 8,
            seed: 48,
            ..SearchConfig::default()
        };
        let t0 = Instant::now();
        let mut correct = 0usize;
        let mut ledger: Option<erprm::coordinator::FlopsLedger> = None;
        for (i, p) in problems.iter().enumerate() {
            let mut c = cfg.clone();
            c.seed = 48 + i as u64;
            if let Ok(out) =
                erprm::coordinator::solve_best_of_n(&engine, "lm-concise", "prm-large", p, &c, 0.5)
            {
                correct += out.correct as usize;
                match &mut ledger {
                    None => ledger = Some(out.ledger),
                    Some(l) => l.merge(&out.ledger),
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        table.row(vec![
            "Best-of-N (no search)".into(),
            format!("{:.1}", 100.0 * correct as f64 / problems.len() as f64),
            fmt_flops(ledger.map(|l| l.total_flops()).unwrap_or(0.0)),
            format!("{wall:.1}"),
            format!("{:.2}", problems.len() as f64 / wall),
        ]);
    }

    let variants: Vec<(&str, RejectPolicy, bool)> = vec![
        ("ER + two-tier (paper)", RejectPolicy::TopK { keep: 4 }, true),
        ("ER, single-tier (b2=b1)", RejectPolicy::TopK { keep: 4 }, false),
        ("ER + threshold policy", RejectPolicy::Threshold { min_score: 0.5, floor: 2 }, true),
        ("ER + adaptive-gap policy", RejectPolicy::AdaptiveGap { keep: 4, min_gap: 0.03 }, true),
    ];
    for (label, policy, two_tier) in variants {
        let cfg = SearchConfig {
            mode: SearchMode::EarlyRejection,
            n_beams: n,
            tau: 8,
            seed: 48,
            ..SearchConfig::default()
        };
        let t0 = Instant::now();
        let mut correct = 0usize;
        let mut ledger = None;
        for (i, p) in problems.iter().enumerate() {
            let mut c = cfg.clone();
            c.seed = 48 + i as u64;
            match solve_early_rejection_with_policy(
                &engine, "lm-concise", "prm-large", p, &c, 0.5, policy, two_tier,
            ) {
                Ok(out) => {
                    correct += out.correct as usize;
                    match &mut ledger {
                        None => ledger = Some(out.ledger),
                        Some(l) => l.merge(&out.ledger),
                    }
                }
                Err(e) => eprintln!("solve failed: {e}"),
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let total = ledger.map(|l| l.total_flops()).unwrap_or(0.0);
        table.row(vec![
            label.into(),
            format!("{:.1}", 100.0 * correct as f64 / problems.len() as f64),
            fmt_flops(total),
            format!("{wall:.1}"),
            format!("{:.2}", problems.len() as f64 / wall),
        ]);
    }
    table.emit("ablation_two_tier");
}
