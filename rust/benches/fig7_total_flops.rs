//! Paper Fig. 7: total FLOPs per LM-PRM combination with and without early
//! rejection — the bar chart's heights as a table. The paper's headline:
//! consistent reductions, up to 9x at the larger tau, with the
//! exploratory-LM (Qwen-analog) combos showing the largest absolute
//! savings (Obs. 5).

mod common;

use erprm::config::SearchMode;
use erprm::harness::{run_cell, Cell};
use erprm::util::benchkit::{fmt_flops, Table};
use erprm::workload::SATMATH;

fn main() {
    let Some(engine) = common::engine() else { return };
    let problems = common::problems(10);
    let n = 16;

    let mut table = Table::new(
        &format!("Fig. 7 — total FLOPs per combo (satmath-s, N={n})"),
        &["combo", "vanilla", "ER(tau=8)", "ER(tau=16)", "best reduction"],
    );
    for (lm, lm_label) in [("lm-concise", "Llama-a"), ("lm-verbose", "Qwen-a")] {
        for (prm, prm_label) in [("prm-large", "Math-7b-a"), ("prm-small", "Skywork-1.5b-a")] {
            let mut flops = Vec::new();
            for (mode, tau) in [
                (SearchMode::Vanilla, 1usize),
                (SearchMode::EarlyRejection, 8),
                (SearchMode::EarlyRejection, 16),
            ] {
                let cell = Cell {
                    bench: SATMATH,
                    lm_ckpt: lm.into(),
                    prm_ckpt: prm.into(),
                    mode,
                    n_beams: n,
                    tau,
                };
                match run_cell(&engine, &cell, problems, 47) {
                    Ok(res) => flops.push(res.ledger.total_flops()),
                    Err(e) => {
                        eprintln!("cell failed: {e}");
                        flops.push(f64::NAN);
                    }
                }
            }
            let best = flops[1..]
                .iter()
                .cloned()
                .fold(f64::INFINITY, f64::min);
            table.row(vec![
                format!("{lm_label}+{prm_label}"),
                fmt_flops(flops[0]),
                fmt_flops(flops[1]),
                fmt_flops(flops[2]),
                format!("{:.2}x", flops[0] / best),
            ]);
        }
    }
    table.emit("fig7_total_flops");
}
