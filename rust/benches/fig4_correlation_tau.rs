//! Paper Fig. 4: Kendall tau and Pearson correlation of (partial, final)
//! rewards as the decision prefix tau sweeps — empirically over real PRM
//! scores AND the sqrt(tau/L) law of the toy model (Sec. 4).

mod common;

use erprm::harness::correlation::{correlation_vs_tau, score_corpus};
use erprm::sim;
use erprm::util::benchkit::Table;
use erprm::workload::MATH500;

fn main() {
    let Some(engine) = common::engine() else { return };
    let n_traces = common::problems(64).max(32);
    let taus = [2usize, 4, 8, 12, 16, 24, 32];

    for prm in ["prm-large", "prm-small"] {
        let traces = match score_corpus(&engine, prm, &MATH500, n_traces, 4077) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("corpus failed: {e}");
                return;
            }
        };
        let mean_len =
            traces.iter().map(|t| t.len).sum::<usize>() as f64 / traces.len() as f64;
        let rows = correlation_vs_tau(&traces, &taus);
        let mut table = Table::new(
            &format!(
                "Fig. 4 — {prm}: correlation vs tau ({n_traces} traces, mean len {mean_len:.0})"
            ),
            &["tau", "pearson", "kendall", "sqrt(tau/L) (toy)"],
        );
        for (tau, p, k) in rows {
            table.row(vec![
                tau.to_string(),
                format!("{p:.3}"),
                format!("{k:.3}"),
                format!("{:.3}", (tau as f64 / mean_len).min(1.0).sqrt()),
            ]);
        }
        table.emit(&format!("fig4_{prm}"));
    }

    // pure toy-model curve (the paper's analytic overlay)
    let mut toy = Table::new("Fig. 4 overlay — i.i.d. toy model, L=32", &["tau", "pearson (MC)", "kendall (MC)", "sqrt(tau/L)"]);
    for tau in [2usize, 4, 8, 16, 24, 32] {
        let (p, k) = sim::toy_correlation(tau, 32, 3000, 9);
        toy.row(vec![
            tau.to_string(),
            format!("{p:.3}"),
            format!("{k:.3}"),
            format!("{:.3}", sim::toy_correlation_exact(tau, 32)),
        ]);
    }
    toy.emit("fig4_toy");
}
