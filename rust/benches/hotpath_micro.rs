//! L3 hot-path microbenches (the perf pass's measurement tool):
//! PJRT call latencies per program class, host-side coordinator costs,
//! and substrate costs (JSON, sampler, policy) — none of which may
//! dominate the decode loop.

mod common;

use std::time::Duration;

use erprm::coordinator::policy::RejectPolicy;
use erprm::coordinator::sampler;
use erprm::tokenizer as tk;
use erprm::util::benchkit::{bench_fn, bench_header};
use erprm::util::json::Json;
use erprm::util::rng::Rng;
use erprm::workload::{gen_problem, SATMATH};

fn main() {
    bench_header("hot-path micro");
    let budget = Duration::from_secs(3);

    // ---------- host-side substrate costs
    let mut rng = Rng::new(1);
    let logits: Vec<f32> = (0..24).map(|_| rng.f32()).collect();
    let r = bench_fn("sampler: first tokens (N=64)", 3, 200, budget, || {
        std::hint::black_box(sampler::sample_first_tokens(&logits, 64, 0.7, &mut rng));
    });
    println!("{}", r.report());

    let keys: Vec<u64> = (0..64).collect();
    let r = bench_fn("sampler: decode key material (B=64)", 3, 200, budget, || {
        std::hint::black_box(sampler::decode_keys(&keys, 7));
    });
    println!("{}", r.report());

    let scored: Vec<(usize, f32)> = (0..64).map(|i| (i, (i as f32 * 0.37) % 1.0)).collect();
    let r = bench_fn("policy: top-N/M select (N=64)", 3, 200, budget, || {
        std::hint::black_box(RejectPolicy::TopK { keep: 16 }.select(&scored));
    });
    println!("{}", r.report());

    let body = r#"{"v0": 61, "ops": [["-",5],["*",6],["+",4]], "mode": "er", "n_beams": 16}"#;
    let r = bench_fn("json: parse /solve body", 3, 500, budget, || {
        std::hint::black_box(Json::parse(body).unwrap());
    });
    println!("{}", r.report());

    // ---------- PJRT call latencies (the real hot path)
    let Some(engine) = common::engine() else { return };
    let mut rng = Rng::new(2);
    let p = gen_problem(&mut rng, &SATMATH);
    let prompt = p.prompt_tokens();

    let r = bench_fn("pjrt: lm prefill b=1", 1, 50, budget, || {
        std::hint::black_box(engine.lm_prefill("lm-concise", &prompt).unwrap());
    });
    println!("{}", r.report());

    for b in [4usize, 16, 64] {
        let (_, kv1) = engine.lm_prefill("lm-concise", &prompt).unwrap();
        let mut kv = engine.kv_broadcast("lm-concise", &kv1, b).unwrap();
        let prev = vec![tk::DIG0; b];
        let keys: Vec<u32> = (0..2 * b as u32).collect();
        let r = bench_fn(&format!("pjrt: lm decode block b={b}"), 2, 40, budget, || {
            if kv.remaining() < 8 {
                kv = engine.kv_broadcast("lm-concise", &kv1, b).unwrap();
            }
            std::hint::black_box(
                engine.lm_decode_block("lm-concise", &mut kv, &prev, 0.7, &keys).unwrap(),
            );
        });
        println!("{}", r.report());
    }

    for b in [4usize, 16] {
        let kv1 = engine.prm_prefill("prm-large", &prompt).unwrap();
        let mut kv = engine.kv_broadcast("prm-large", &kv1, b).unwrap();
        let tokens = vec![tk::DIG0; b * engine.manifest.score_block];
        let r = bench_fn(&format!("pjrt: prm-large score block b={b}"), 2, 30, budget, || {
            if kv.remaining() < 32 {
                kv = engine.kv_broadcast("prm-large", &kv1, b).unwrap();
            }
            std::hint::black_box(engine.prm_score_block("prm-large", &mut kv, &tokens).unwrap());
        });
        println!("{}", r.report());
    }

    let (_, kv1) = engine.lm_prefill("lm-concise", &prompt).unwrap();
    let kv = engine.kv_broadcast("lm-concise", &kv1, 16).unwrap();
    let idx: Vec<i32> = (0..16).rev().collect();
    let mut kvm = kv;
    let r = bench_fn("pjrt: kv gather b=16", 2, 50, budget, || {
        engine.kv_gather("lm-concise", &mut kvm, &idx).unwrap();
    });
    println!("{}", r.report());

    let stats = engine.stats();
    println!(
        "\nengine stats: {} executions, {:.2}s exec wall, {} compiles ({:.1}s), {:.1} MiB up / {:.1} MiB down",
        stats.executions,
        stats.execute_wall_s,
        stats.compiles,
        stats.compile_wall_s,
        stats.host_bytes_up as f64 / (1 << 20) as f64,
        stats.host_bytes_down as f64 / (1 << 20) as f64,
    );
}
