//! Quickstart: load the AOT artifacts, solve one arithmetic-chain problem
//! with both decoders, and compare the FLOPs bill.
//!
//!     make artifacts && cargo run --release --example quickstart

use erprm::config::{SearchConfig, SearchMode};
use erprm::coordinator::{solve_early_rejection, solve_vanilla};
use erprm::runtime::Engine;
use erprm::tokenizer as tk;
use erprm::util::benchkit::fmt_flops;
use erprm::workload::{OpStep, Problem};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    erprm::util::logging::init_from_env();
    let engine = Engine::load(std::path::Path::new("artifacts"))?;

    // (61 - 5) * 6 + 4 mod 100
    let problem = Problem {
        v0: 61,
        ops: vec![
            OpStep { op: tk::MINUS, d: 5 },
            OpStep { op: tk::TIMES, d: 6 },
            OpStep { op: tk::PLUS, d: 4 },
        ],
    };
    println!("problem: {}  (answer: {})", tk::detok(&problem.prompt_tokens()), problem.answer());

    let cfg = SearchConfig { n_beams: 16, tau: 8, seed: 1, ..SearchConfig::default() };

    let mut vanilla_cfg = cfg.clone();
    vanilla_cfg.mode = SearchMode::Vanilla;
    let vanilla = solve_vanilla(&engine, "lm-concise", "prm-large", &problem, &vanilla_cfg, 0.5)?;
    let er = solve_early_rejection(&engine, "lm-concise", "prm-large", &problem, &cfg, 0.5)?;

    for (name, out) in [("vanilla (Alg. 2)", &vanilla), ("early rejection (Alg. 3)", &er)] {
        println!("\n== {name}");
        println!("trace:  {}", tk::detok(&out.best_trace));
        println!(
            "answer: {:?}  correct: {}  reward: {:.3}",
            out.answer, out.correct, out.best_reward
        );
        let r = out.ledger.report();
        println!(
            "flops:  {} total ({} LM + {} PRM), {:.0}ms",
            fmt_flops(r.total_flops),
            fmt_flops(r.lm_flops),
            fmt_flops(r.prm_flops),
            out.wall_s * 1000.0
        );
    }
    println!(
        "\nearly rejection used {:.2}x fewer FLOPs",
        vanilla.ledger.total_flops() / er.ledger.total_flops()
    );
    Ok(())
}
