//! Section 4 theory validation: Monte-Carlo check of the sqrt(tau/L)
//! correlation law and the sub-Gaussian early-rejection safety bound.
//! Pure simulation — runs without artifacts.
//!
//!     cargo run --release --example theory_validation

use erprm::sim;

fn main() {
    let trials = 8000;
    println!("== rho(P, F) = sqrt(tau/L), L = 64, {trials} trials ==");
    println!("{:>5} {:>12} {:>12} {:>12}", "tau", "pearson(MC)", "kendall(MC)", "exact");
    for tau in [4usize, 8, 16, 24, 32, 48, 64] {
        let (p, k) = sim::toy_correlation(tau, 64, trials, 7);
        println!("{tau:>5} {p:>12.3} {k:>12.3} {:>12.3}", sim::toy_correlation_exact(tau, 64));
    }

    println!("\n== Pr[prune optimal] vs (N-1)exp(-Delta^2/4sigma^2), N=16 M=4 ==");
    println!("{:>5} {:>8} {:>12} {:>10}", "tau", "delta", "empirical", "bound");
    for &(tau, d) in &[(4usize, 0.25f64), (8, 0.25), (16, 0.25), (32, 0.25), (64, 0.25), (16, 1.0)] {
        let (emp, bound) = sim::prune_probability(16, 4, tau, d, 1.0, trials, 11);
        println!("{tau:>5} {d:>8.2} {emp:>12.4} {bound:>10.4}");
        assert!(emp <= bound + 0.02, "bound violated!");
    }
    println!("\nbound holds everywhere; decay is exponential in tau * delta^2 (paper Sec. 4).");
    println!(
        "min tau for rho*=0.8 at L=100: {} tokens (paper: 0.64 L = 64)",
        sim::min_tau_for_rho(0.8, 100)
    );
}
