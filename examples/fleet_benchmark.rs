//! Fleet-vs-sequential-vs-gang serving benchmark (the acceptance driver
//! for the fleet scheduler and the gang batcher): fires one mixed
//! workload at three pools with the *same shard count* — sequential
//! dispatch, the fleet scheduler, and the fleet scheduler with gang
//! batching (`--gang` semantics of `erprm serve`) — and reports aggregate
//! solves/sec, latency percentiles, queue wait, scheduler counters, the
//! gang batcher's acceptance metric — **engine decode invocations per
//! completed request** (shared batches must lower it, not just shuffle
//! work) — and **effective cache utilization** (1 - junk share of
//! attended positions): gang mode's max-frontier union gap must be
//! reclaimed by KV re-compaction, not paid as shrinking effective cache
//! length.
//!
//! The workload is deliberately mixed: requests vary in beam width (long
//! and short solves interleaved, so sequential dispatch head-of-line
//! blocks) and popular problems repeat (`--dup`, so single-flight
//! coalescing pays once for duplicate in-flight work, like production
//! traffic hitting a hot prompt).
//!
//! A fourth run, `fleet+paged` (`--kv-pool-blocks`, default 4096 per
//! shard; 0 disables), replays the same traffic through the fleet
//! scheduler with KV in a shared per-shard block pool. Its acceptance
//! criteria are printed at the end: every outcome byte-identical to the
//! dense fleet run, and the pool's high-water mark below the dense-cache
//! equivalent (per-slot caches padded to the batch variant and pinned for
//! the full cache length across `max_inflight` requests per shard).
//!
//! A fifth run, `gang+native`, replays the traffic through the gang
//! scheduler with the manifest-default block pool — block-native
//! attention when the artifact set exports blocktab programs. Its
//! acceptance criteria: outcomes byte-identical to the dense gang run
//! with (near-)zero merge/compact *device* calls, the gang assembly
//! having collapsed into block-table edits.
//!
//!     make artifacts && cargo run --release --example fleet_benchmark -- \
//!         --requests 32 --clients 8 --shards 2 --max-inflight 8 --dup 4
//!
//! `--trace-out trace.json` additionally writes the gang run's request
//! traces as a Chrome `trace_event` timeline (open in Perfetto or
//! chrome://tracing; shards are processes, slots are threads). Each run
//! also reports the early-rejection ledger — beams rejected and
//! estimated FLOPs saved — from the per-request trace recorder.
//!
//! The LRU cache is off in all pools so the comparison measures the
//! schedulers, not the cache. Gang mode needs artifacts exported with
//! `merge_bA_bB_to_bC` programs; older artifact sets degrade to all-solo
//! calls (the gang counters will read zero).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use erprm::config::{SearchConfig, SearchMode};
use erprm::fleet::FleetOptions;
use erprm::obs::{chrome_trace, CalibOptions, SamplePolicy, Trace, TraceOptions};
use erprm::runtime::Manifest;
use erprm::server::api::SolveRequest;
use erprm::server::{EnginePool, PoolOptions};
use erprm::util::benchkit::fmt_flops;
use erprm::util::cli::Args;
use erprm::util::json::Json;
use erprm::util::rng::Rng;
use erprm::util::stats;
use erprm::util::threadpool::{parallel_map, ThreadPool};
use erprm::workload::{gen_problem, SATMATH};

struct Report {
    label: String,
    wall_s: f64,
    rps: f64,
    p50_ms: f64,
    p95_ms: f64,
    mean_queue_wait_ms: f64,
    errors: usize,
    engine_solves: u64,
    decode_calls: u64,
    score_calls: u64,
    /// Effective cache utilization: 1 - junk share of all cache positions
    /// the engines attended over (compaction's acceptance metric — gang
    /// mode must not pay for its max-frontier union gap in junk).
    cache_util: f64,
    /// Device KV-concat merge calls (gang assembly). Block-native runs
    /// must hold this at ~0 for ganged traffic — merges become table
    /// edits, counted separately below.
    merge_calls: u64,
    compact_calls: u64,
    compact_reclaimed: u64,
    /// Host block-table edits (block-native runs only; zero elsewhere).
    table_merges: u64,
    table_splits: u64,
    table_compacts: u64,
    /// Block-pool footprint (zero on dense runs): high-water mark and
    /// total, summed across shards.
    pool_hwm: u64,
    pool_total: u64,
    /// Early-rejection ledger rollups from the pool's trace recorder
    /// (exact — accumulated before trace sampling).
    er_beams_rejected: u64,
    er_flops_saved: f64,
    /// Retained request traces, for the `--trace-out` Chrome export.
    traces: Vec<Arc<Trace>>,
    fleet_line: String,
    gang_line: String,
}

/// Per-request outcome digest for cross-mode byte-identity checks
/// (None where the request failed).
type Digest = Option<(Option<i64>, usize, Vec<i32>)>;

/// Results of the adaptive-tau leg (two passes over one pool, so the
/// warm pass's calibration table carries into the measured pass).
struct AdaptiveLeg {
    wall_s: f64,
    rps: f64,
    errors: usize,
    er_beams_rejected: u64,
    er_flops_saved: f64,
    /// Requests whose measured-pass final answer matches the static
    /// fleet run's answer for the same request.
    answers_match: usize,
    /// `GET /calibration` document of the warmed table.
    calib_json: String,
}

#[allow(clippy::too_many_arguments)]
fn run_mode(
    label: &str,
    dir: PathBuf,
    shards: usize,
    capacity: usize,
    fleet: Option<FleetOptions>,
    kv_pool_blocks: Option<usize>,
    trace: TraceOptions,
    clients: usize,
    requests: &[SolveRequest],
) -> Result<(Report, Vec<Digest>), Box<dyn std::error::Error>> {
    // LRU cache and pool single-flight both off: the comparison measures
    // the schedulers (and in-shard coalescing), not pool-level dedup
    let pool = EnginePool::spawn_with(
        dir,
        PoolOptions {
            shards,
            capacity,
            cache_entries: 0,
            default_deadline_ms: 0,
            fleet,
            singleflight: false,
            kv_pool_blocks,
            trace,
            ..PoolOptions::default()
        },
    )?;
    let client_pool = ThreadPool::new(clients);
    let p2 = pool.clone();
    let t0 = Instant::now();
    let results = parallel_map(&client_pool, requests.to_vec(), move |req| {
        let t = Instant::now();
        let cfg = SearchConfig { seed: 7, ..SearchConfig::default() };
        let res = p2.solve_timed(req, cfg);
        (t.elapsed().as_secs_f64() * 1000.0, res)
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let mut latencies = Vec::new();
    let mut queue_waits = Vec::new();
    let mut errors = 0usize;
    let mut digests: Vec<Digest> = Vec::with_capacity(results.len());
    for (ms, res) in &results {
        latencies.push(*ms);
        match res {
            Ok(s) => {
                queue_waits.push(s.queue_wait_ms);
                digests.push(Some((
                    s.outcome.answer,
                    s.outcome.steps_executed,
                    s.outcome.best_trace.clone(),
                )));
            }
            Err(e) => {
                errors += 1;
                digests.push(None);
                eprintln!("[{label}] request failed: {e}");
            }
        }
    }
    let fleet_line = match pool.fleet_totals() {
        Some(t) => format!(
            "admitted {} backfill {} coalesced {} expired {}",
            t.admitted, t.backfill, t.coalesced, t.expired
        ),
        None => "-".to_string(),
    };
    let gang_line = match pool.batch_totals() {
        Some(b) => format!(
            "gangs {} ganged {} solo {} merged-slots {} padding {} precompacts {}",
            b.gangs, b.ganged_intents, b.solo_intents, b.merged_slots, b.padding_slots,
            b.precompacts
        ),
        None => "-".to_string(),
    };
    let es = pool.engine_stats();
    let tr = pool.tracer().totals();
    let report = Report {
        label: label.to_string(),
        wall_s,
        rps: requests.len() as f64 / wall_s,
        p50_ms: stats::quantile(&latencies, 0.5),
        p95_ms: stats::quantile(&latencies, 0.95),
        mean_queue_wait_ms: stats::mean(&queue_waits),
        errors,
        engine_solves: pool.shard_solves().iter().sum(),
        decode_calls: es.decode_calls,
        score_calls: es.score_calls,
        cache_util: 1.0 - es.junk_fraction(),
        merge_calls: es.merge_calls,
        compact_calls: es.compact_calls,
        compact_reclaimed: es.compact_reclaimed,
        table_merges: es.table_merges,
        table_splits: es.table_splits,
        table_compacts: es.table_compacts,
        pool_hwm: es.pool_hwm,
        pool_total: es.pool_blocks_total,
        er_beams_rejected: tr.er_beams_rejected,
        er_flops_saved: tr.er_flops_saved,
        traces: pool.tracer().all(),
        fleet_line,
        gang_line,
    };
    pool.shutdown();
    Ok((report, digests))
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    erprm::util::logging::init_from_env();
    let args = Args::from_env()?;
    let n_requests = args.get_usize("requests", 24)?;
    let clients = args.get_usize_min("clients", 8, 1)?;
    let shards = args.get_usize_min("shards", 2, 1)?;
    let capacity = args.get_usize_min("capacity", 64, 1)?;
    let max_inflight = args.get_usize_min("max-inflight", 8, 1)?;
    // every unique problem is requested `dup` times (hot-prompt traffic)
    let dup = args.get_usize_min("dup", 4, 1)?;
    let gang_max_wait = args.get_u64("gang-max-wait", 1)?;
    // per-shard block-pool size for the fleet+paged run; 0 skips it
    let kv_pool_blocks = args.get_usize("kv-pool-blocks", 4096)?;
    // --trace-out PATH: Chrome trace_event timeline of the gang run
    // (load it in Perfetto / chrome://tracing)
    let trace_out = args.get("trace-out").map(str::to_string);
    // --json-out PATH: machine-readable run summary (per-mode throughput,
    // decode invocations/request, ER ledger, adaptive-tau acceptance and
    // the warmed calibration table) for CI smoke legs and dashboards
    let json_out = args.get("json-out").map(str::to_string);
    // --trace-sample F: success-trace retention rate (failures always kept)
    let trace_sample = args.get_f64("trace-sample", 1.0)?.clamp(0.0, 1.0);

    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("artifacts missing; run `make artifacts` first (skipping benchmark)");
        // still honor --trace-out so trace-consuming pipelines (CI smoke
        // included) get a valid, if empty, Chrome trace document
        if let Some(path) = &trace_out {
            std::fs::write(path, chrome_trace(&[]).to_string())?;
            println!("wrote empty Chrome trace to {path}");
        }
        // likewise --json-out: a schema-valid, if empty, summary
        if let Some(path) = &json_out {
            let doc = Json::obj(vec![
                ("requests", Json::num(0.0)),
                ("modes", Json::Arr(vec![])),
                ("adaptive", Json::Null),
            ]);
            std::fs::write(path, doc.to_string())?;
            println!("wrote empty benchmark summary to {path}");
        }
        return Ok(());
    }

    // One shared workload so every mode sees identical requests: unique
    // problems at mixed beam widths, each repeated `dup` times, then
    // shuffled so duplicates overlap in flight instead of back-to-back.
    let widths = [4usize, 8, 16];
    let mut rng = Rng::new(2718);
    let uniques = n_requests.div_ceil(dup);
    let mut requests: Vec<SolveRequest> = Vec::with_capacity(n_requests);
    for i in 0..uniques {
        let p = gen_problem(&mut rng, &SATMATH);
        let n_beams = widths[i % widths.len()];
        for _ in 0..dup {
            if requests.len() == n_requests {
                break;
            }
            requests.push(SolveRequest {
                problem: p.clone(),
                mode: SearchMode::EarlyRejection,
                n_beams,
                tau: 8,
                lm: "lm-concise".into(),
                prm: "prm-large".into(),
                deadline_ms: None,
                priority: 0,
                request_id: String::new(),
            });
        }
    }
    rng.shuffle(&mut requests); // duplicates spread out, not back-to-back

    // Retain every request's trace (modulo --trace-sample) with the rate
    // limiter effectively off — a benchmark burst is exactly the traffic
    // the serve-time default would clip, and we want a full timeline.
    let topts = TraceOptions {
        capacity: requests.len().max(1),
        sample: SamplePolicy {
            success_rate: trace_sample,
            max_per_sec: 1e12,
            burst: 1e12,
            ..SamplePolicy::default()
        },
        calib: CalibOptions::default(),
    };

    println!(
        "firing {} requests ({} unique problems x{dup}, widths {widths:?}) from {clients} \
         client threads at {shards} shard(s)",
        requests.len(),
        uniques
    );

    // the three dense baselines force Some(0): with `None` the pool now
    // defaults to the manifest's exported pool sizing, which would turn
    // the dense runs paged on block-native artifact sets
    let (seq, _) = run_mode(
        "sequential",
        "artifacts".into(),
        shards,
        capacity,
        None,
        Some(0),
        topts,
        clients,
        &requests,
    )?;
    let (fleet, fleet_digests) = run_mode(
        "fleet",
        "artifacts".into(),
        shards,
        capacity,
        Some(FleetOptions { max_inflight, ..FleetOptions::default() }),
        Some(0),
        topts,
        clients,
        &requests,
    )?;
    let (gang, gang_digests) = run_mode(
        "gang",
        "artifacts".into(),
        shards,
        capacity,
        Some(FleetOptions { max_inflight, gang: true, gang_max_wait, ..FleetOptions::default() }),
        Some(0),
        topts,
        clients,
        &requests,
    )?;

    // fleet+paged: identical scheduler and traffic, KV in the block pool.
    // Needs artifacts exported with kv_block; older sets skip (the runtime
    // would silently fall back to dense, making the comparison vacuous).
    let manifest = Manifest::load(Path::new("artifacts"))?;
    let paged = match (kv_pool_blocks, manifest.kv_block) {
        (0, _) => None,
        (_, None) => {
            println!("\nartifacts predate paged export (no kv_block); skipping fleet+paged run");
            None
        }
        (blocks, Some(_)) => Some(run_mode(
            "fleet+paged",
            "artifacts".into(),
            shards,
            capacity,
            Some(FleetOptions { max_inflight, ..FleetOptions::default() }),
            Some(blocks),
            topts,
            clients,
            &requests,
        )?),
    };

    // gang+native: gang batching over the manifest-default block pool —
    // block-native attention when the artifact set exports blocktab
    // programs. Tentpole acceptance: outcomes byte-identical to the
    // dense gang run with zero merge/compact device calls.
    let native = match (kv_pool_blocks, manifest.pool_blocks) {
        (0, _) => None,
        (_, None) => {
            println!("\nartifacts predate block-native export (no pool_blocks); skipping gang+native run");
            None
        }
        (_, Some(_)) => Some(run_mode(
            "gang+native",
            "artifacts".into(),
            shards,
            capacity,
            Some(FleetOptions { max_inflight, gang: true, gang_max_wait, ..FleetOptions::default() }),
            None, // manifest-default pool sizing
            topts,
            clients,
            &requests,
        )?),
    };

    // fleet+adaptive: the same scheduler and traffic with the
    // calibration loop closed. Two passes over ONE pool: the warm pass
    // streams partial↔final pairs into the table (the controller stays
    // effectively static until buckets prove out), then the measured
    // pass runs with the warmed table, each request's plan frozen at
    // dispatch. Shadow sampling is off so the measured pass decodes
    // nothing beyond what its plans call for.
    let adaptive: AdaptiveLeg = {
        let calib = CalibOptions {
            adaptive: true,
            // the bench workload is small; trust buckets sooner than the
            // serve-time default so one warm pass can prove them out
            min_samples: 16,
            shadow_rate: 0.0,
            ..CalibOptions::default()
        };
        let pool = EnginePool::spawn_with(
            "artifacts".into(),
            PoolOptions {
                shards,
                capacity,
                cache_entries: 0,
                default_deadline_ms: 0,
                fleet: Some(FleetOptions { max_inflight, ..FleetOptions::default() }),
                singleflight: false,
                kv_pool_blocks: Some(0),
                trace: TraceOptions { calib, ..topts },
                ..PoolOptions::default()
            },
        )?;
        let client_pool = ThreadPool::new(clients);
        let pass = |reqs: &[SolveRequest]| {
            let p2 = pool.clone();
            let t0 = Instant::now();
            let results = parallel_map(&client_pool, reqs.to_vec(), move |req| {
                let cfg = SearchConfig { seed: 7, ..SearchConfig::default() };
                p2.solve_timed(req, cfg)
            });
            (t0.elapsed().as_secs_f64(), results)
        };
        let (warm_s, warm_results) = pass(&requests);
        let warm_errors = warm_results.iter().filter(|r| r.is_err()).count();
        let warm_tr = pool.tracer().totals();
        let (wall_s, results) = pass(&requests);
        let tr = pool.tracer().totals();
        let errors = results.iter().filter(|r| r.is_err()).count();
        let answers_match = fleet_digests
            .iter()
            .zip(&results)
            .filter(|(d, r)| match (d, r) {
                (Some((ans, _, _)), Ok(s)) => *ans == s.outcome.answer,
                _ => false,
            })
            .count();
        let calib_json = pool.calibration_json();
        pool.shutdown();
        println!(
            "\nadaptive warm pass: {warm_s:.2}s, {warm_errors} errors \
             ({} samples streamed into the calibration table)",
            Json::parse(&calib_json)
                .ok()
                .and_then(|j| j.get("samples_total").and_then(Json::as_f64))
                .unwrap_or(0.0)
        );
        AdaptiveLeg {
            wall_s,
            rps: requests.len() as f64 / wall_s,
            errors,
            er_beams_rejected: tr.er_beams_rejected - warm_tr.er_beams_rejected,
            er_flops_saved: tr.er_flops_saved - warm_tr.er_flops_saved,
            answers_match,
            calib_json,
        }
    };

    println!("\n== sequential vs fleet vs gang (equal shard count) ==");
    println!(
        "{:<12} {:>8} {:>11} {:>8} {:>8} {:>11} {:>6} {:>8} {:>10} {:>10} {:>10}",
        "mode", "wall s", "solves/sec", "p50 ms", "p95 ms", "queue-wait", "errs", "solves",
        "decodes", "decode/req", "cache-util"
    );
    let mut rows = vec![&seq, &fleet, &gang];
    if let Some((r, _)) = &paged {
        rows.push(r);
    }
    if let Some((r, _)) = &native {
        rows.push(r);
    }
    for r in &rows {
        println!(
            "{:<12} {:>8.2} {:>11.2} {:>8.0} {:>8.0} {:>11.1} {:>6} {:>8} {:>10} {:>10.1} \
             {:>9.1}%",
            r.label,
            r.wall_s,
            r.rps,
            r.p50_ms,
            r.p95_ms,
            r.mean_queue_wait_ms,
            r.errors,
            r.engine_solves,
            r.decode_calls,
            r.decode_calls as f64 / requests.len() as f64,
            100.0 * r.cache_util,
        );
    }
    // Per-mode early-rejection ledger, from the per-request trace
    // recorder rather than engine counters: same ER search, so the modes
    // should agree — a divergence means a scheduler dropped or duplicated
    // rejection work.
    println!("\n== early-rejection ledger (per mode, from request traces) ==");
    for r in &rows {
        println!(
            "{:<12} beams rejected {:>8}  est FLOPs saved {:>10}",
            r.label,
            r.er_beams_rejected,
            fmt_flops(r.er_flops_saved),
        );
    }

    println!("\nfleet counters: fleet [{}]  gang [{}]", fleet.fleet_line, gang.fleet_line);
    println!("gang counters:  {}", gang.gang_line);
    println!(
        "kv compaction:  seq {} calls/{} reclaimed  fleet {}/{}  gang {}/{}",
        seq.compact_calls,
        seq.compact_reclaimed,
        fleet.compact_calls,
        fleet.compact_reclaimed,
        gang.compact_calls,
        gang.compact_reclaimed,
    );
    let ratio = gang.rps / seq.rps.max(1e-9);
    let decode_ratio = gang.decode_calls as f64 / fleet.decode_calls.max(1) as f64;
    println!(
        "\ngang / sequential = {ratio:.2}x aggregate solves/sec; gang ran {:.2}x the decode \
         invocations of plain fleet for the same {} requests ({} vs {}; score calls {} vs {}); \
         effective cache utilization gang {:.1}% vs fleet {:.1}%",
        decode_ratio,
        requests.len(),
        gang.decode_calls,
        fleet.decode_calls,
        gang.score_calls,
        fleet.score_calls,
        100.0 * gang.cache_util,
        100.0 * fleet.cache_util,
    );

    if let Some((pr, paged_digests)) = &paged {
        let bs = manifest.kv_block.unwrap();
        // Dense-cache equivalent at equal traffic: per admitted request the
        // dense engine pins LM + PRM caches padded to the batch variant for
        // the full cache length, and the fleet admits up to max_inflight
        // per shard. Sized at the widest request in the workload, like the
        // capacity planning a dense deployment has to do.
        let variant = |n: usize| {
            manifest.batch_variants.iter().copied().filter(|&v| v >= n).min().unwrap_or(n)
        };
        let lm_nb = manifest.model("lm-concise")?.cache_len.div_ceil(bs);
        let prm_nb = manifest.model("prm-large")?.cache_len.div_ceil(bs);
        let widest = widths.iter().copied().max().unwrap();
        let dense_equiv = (shards * max_inflight * variant(widest) * (lm_nb + prm_nb)) as u64;
        let mismatches =
            fleet_digests.iter().zip(paged_digests).filter(|(a, b)| a != b).count();
        println!(
            "\n== paged KV acceptance (fleet+paged vs fleet, {} blocks/shard of {} tokens) ==",
            kv_pool_blocks, bs
        );
        println!(
            "outcomes byte-identical: {} ({} of {} requests match)",
            if mismatches == 0 { "yes" } else { "NO" },
            requests.len() - mismatches,
            requests.len(),
        );
        println!(
            "pool high-water mark {} blocks vs dense-cache equivalent {} blocks \
             ({} shards x {} inflight x b{} x {} blocks/request): {}",
            pr.pool_hwm,
            dense_equiv,
            shards,
            max_inflight,
            variant(widest),
            lm_nb + prm_nb,
            if pr.pool_hwm < dense_equiv { "BELOW (pass)" } else { "not below" },
        );
        println!(
            "pool total {} blocks/fleet; throughput {:.2} solves/sec vs fleet {:.2}",
            pr.pool_total, pr.rps, fleet.rps,
        );
    }

    if let Some((nr, native_digests)) = &native {
        let mismatches =
            gang_digests.iter().zip(native_digests).filter(|(a, b)| a != b).count();
        println!("\n== block-native acceptance (gang+native vs gang, manifest-default pool) ==");
        println!(
            "outcomes byte-identical: {} ({} of {} requests match)",
            if mismatches == 0 { "yes" } else { "NO" },
            requests.len() - mismatches,
            requests.len(),
        );
        println!(
            "device calls: merges {} (dense gang ran {}), compactions {} (dense gang ran {}): {}",
            nr.merge_calls,
            gang.merge_calls,
            nr.compact_calls,
            gang.compact_calls,
            if nr.merge_calls == 0 && nr.compact_calls == 0 {
                "ZERO (pass)"
            } else {
                "not zero — gather-paged fallback?"
            },
        );
        println!(
            "table edits instead: merges {}, splits {}, compactions {}; pool hwm {} of {} blocks; \
             throughput {:.2} solves/sec vs dense gang {:.2}",
            nr.table_merges,
            nr.table_splits,
            nr.table_compacts,
            nr.pool_hwm,
            nr.pool_total,
            nr.rps,
            gang.rps,
        );
    }

    println!("\n== adaptive tau (fleet+adaptive vs fleet, warmed calibration table) ==");
    println!(
        "measured pass {:.2}s, {:.2} solves/sec, {} errors",
        adaptive.wall_s, adaptive.rps, adaptive.errors
    );
    println!(
        "ER FLOPs saved: adaptive {} (beams {}) vs static fleet {} (beams {}): {}",
        fmt_flops(adaptive.er_flops_saved),
        adaptive.er_beams_rejected,
        fmt_flops(fleet.er_flops_saved),
        fleet.er_beams_rejected,
        if adaptive.er_flops_saved >= fleet.er_flops_saved {
            "GEQ (pass)"
        } else {
            "below static"
        },
    );
    println!(
        "final answers identical to static fleet: {} of {} ({})",
        adaptive.answers_match,
        requests.len(),
        if adaptive.answers_match == requests.len() { "pass" } else { "DIVERGED" },
    );

    if let Some(path) = &trace_out {
        // Export the gang run: it exercises the widest span vocabulary
        // (queue, gang:decode/gang:score members, compaction, ER events).
        std::fs::write(path, chrome_trace(&gang.traces).to_string())?;
        println!(
            "\nwrote Chrome trace_event timeline of the gang run ({} traces) to {path} \
             — open in Perfetto or chrome://tracing",
            gang.traces.len()
        );
    }

    if let Some(path) = &json_out {
        let mode_json = |r: &Report| {
            Json::obj(vec![
                ("label", Json::str(r.label.clone())),
                ("wall_s", Json::num(r.wall_s)),
                ("solves_per_sec", Json::num(r.rps)),
                ("p50_ms", Json::num(r.p50_ms)),
                ("p95_ms", Json::num(r.p95_ms)),
                ("errors", Json::num(r.errors as f64)),
                ("engine_solves", Json::num(r.engine_solves as f64)),
                ("decode_calls", Json::num(r.decode_calls as f64)),
                (
                    "decode_per_request",
                    Json::num(r.decode_calls as f64 / requests.len().max(1) as f64),
                ),
                ("er_beams_rejected", Json::num(r.er_beams_rejected as f64)),
                ("er_flops_saved", Json::num(r.er_flops_saved)),
            ])
        };
        let doc = Json::obj(vec![
            ("requests", Json::num(requests.len() as f64)),
            ("unique_problems", Json::num(uniques as f64)),
            ("dup", Json::num(dup as f64)),
            ("shards", Json::num(shards as f64)),
            ("modes", Json::Arr(rows.iter().map(|r| mode_json(r)).collect())),
            (
                "adaptive",
                Json::obj(vec![
                    ("wall_s", Json::num(adaptive.wall_s)),
                    ("solves_per_sec", Json::num(adaptive.rps)),
                    ("errors", Json::num(adaptive.errors as f64)),
                    ("er_beams_rejected", Json::num(adaptive.er_beams_rejected as f64)),
                    ("er_flops_saved", Json::num(adaptive.er_flops_saved)),
                    ("static_er_flops_saved", Json::num(fleet.er_flops_saved)),
                    (
                        "flops_saved_geq_static",
                        Json::Bool(adaptive.er_flops_saved >= fleet.er_flops_saved),
                    ),
                    ("answers_match_static", Json::num(adaptive.answers_match as f64)),
                    (
                        "calibration",
                        Json::parse(&adaptive.calib_json).unwrap_or(Json::Null),
                    ),
                ]),
            ),
        ]);
        std::fs::write(path, doc.to_string())?;
        println!("wrote machine-readable summary to {path}");
    }
    Ok(())
}
