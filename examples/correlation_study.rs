//! Correlation study (paper Figs. 2 & 4 on real engines): scores a trace
//! corpus with both PRMs via the Pallas prefix-score kernel and prints the
//! partial-vs-final fit and the correlation-vs-tau sweep.
//!
//!     make artifacts && cargo run --release --example correlation_study

use erprm::harness::correlation::{correlation_vs_tau, half_vs_final_fit, score_corpus};
use erprm::runtime::Engine;
use erprm::workload::MATH500;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    erprm::util::logging::init_from_env();
    let engine = Engine::load(std::path::Path::new("artifacts"))?;
    let n_traces = std::env::var("ERPRM_TRACES").ok().and_then(|v| v.parse().ok()).unwrap_or(48);

    for prm in ["prm-large", "prm-small"] {
        println!("\n==== {prm} over {n_traces} math500-s traces ====");
        let traces = score_corpus(&engine, prm, &MATH500, n_traces, 7)?;
        let mean_len = traces.iter().map(|t| t.len).sum::<usize>() as f64 / traces.len() as f64;

        let (fit, _) = half_vs_final_fit(&traces);
        println!(
            "Fig. 2 fit: final = {:.3} + {:.3} * partial(half),  R^2 = {:.3}  (paper: 0.63-0.72)",
            fit.intercept, fit.slope, fit.r2
        );

        println!("Fig. 4 sweep (mean step-trace len {mean_len:.0}):");
        println!("{:>5} {:>9} {:>9} {:>12}", "tau", "pearson", "kendall", "sqrt(tau/L)");
        for (tau, p, k) in correlation_vs_tau(&traces, &[2, 4, 8, 12, 16, 24, 32]) {
            println!(
                "{tau:>5} {p:>9.3} {k:>9.3} {:>12.3}",
                (tau as f64 / mean_len).min(1.0).sqrt()
            );
        }
    }
    Ok(())
}
