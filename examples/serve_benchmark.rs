//! End-to-end serving driver (the mandated e2e validation): starts the
//! HTTP server on a real socket, loads the trained LM + PRM through the
//! PJRT runtime, fires a batch of concurrent /solve requests from client
//! threads, and reports accuracy, latency percentiles and throughput.
//!
//!     make artifacts && cargo run --release --example serve_benchmark
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end serving.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Instant;

use erprm::config::SearchConfig;
use erprm::server::{api, http, metrics::Metrics, router::EngineHandle};
use erprm::tokenizer as tk;
use erprm::util::json::Json;
use erprm::util::rng::Rng;
use erprm::util::stats;
use erprm::util::threadpool::ThreadPool;
use erprm::workload::{gen_problem, SATMATH};

fn post_solve(addr: std::net::SocketAddr, body: &str) -> Result<Json, String> {
    let mut s = TcpStream::connect(addr).map_err(|e| e.to_string())?;
    let req = format!(
        "POST /solve HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    s.write_all(req.as_bytes()).map_err(|e| e.to_string())?;
    let mut out = String::new();
    s.read_to_string(&mut out).map_err(|e| e.to_string())?;
    let body = out.split("\r\n\r\n").nth(1).ok_or("no body")?;
    Json::parse(body).map_err(|e| e.to_string())
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    erprm::util::logging::init_from_env();
    let n_requests: usize = std::env::var("ERPRM_REQUESTS").ok().and_then(|v| v.parse().ok()).unwrap_or(16);
    let clients = 4;

    // ---- server side
    let defaults = SearchConfig { n_beams: 8, tau: 8, ..SearchConfig::default() };
    let handle = EngineHandle::spawn("artifacts".into(), defaults.clone(), 64)?;
    let metrics = Arc::new(Metrics::default());
    let pool = ThreadPool::new(clients);
    let stop = Arc::new(AtomicBool::new(false));
    let h2 = handle.clone();
    let m2 = Arc::clone(&metrics);
    let d2 = defaults.clone();
    let addr = http::serve(
        "127.0.0.1:0",
        &pool,
        1 << 20,
        Arc::clone(&stop),
        Arc::new(move |req| route(&h2, &m2, &d2, req)),
    )?;
    println!("server up on http://{addr}; firing {n_requests} requests from {clients} client threads");

    // ---- client side: concurrent requests
    let mut rng = Rng::new(314);
    let bodies: Vec<String> = (0..n_requests)
        .map(|_| {
            let p = gen_problem(&mut rng, &SATMATH);
            let ops: Vec<String> = p
                .ops
                .iter()
                .map(|s| {
                    format!(
                        "[\"{}\",{}]",
                        match s.op {
                            tk::PLUS => "+",
                            tk::MINUS => "-",
                            _ => "*",
                        },
                        s.d
                    )
                })
                .collect();
            format!(
                "{{\"v0\": {}, \"ops\": [{}], \"mode\": \"er\", \"n_beams\": 8, \"tau\": 8}}",
                p.v0,
                ops.join(",")
            )
        })
        .collect();

    let client_pool = ThreadPool::new(clients);
    let t0 = Instant::now();
    let results = erprm::util::threadpool::parallel_map(&client_pool, bodies, move |body| {
        let t = Instant::now();
        let resp = post_solve(addr, &body);
        (t.elapsed().as_secs_f64() * 1000.0, resp)
    });
    let wall = t0.elapsed().as_secs_f64();

    // ---- report
    let mut latencies = Vec::new();
    let mut correct = 0usize;
    let mut flops_total = 0.0;
    let mut errors = 0usize;
    for (ms, resp) in &results {
        latencies.push(*ms);
        match resp {
            Ok(j) => {
                correct += (j.get("correct").and_then(Json::as_bool) == Some(true)) as usize;
                flops_total += j.get("flops").and_then(Json::as_f64).unwrap_or(0.0);
            }
            Err(e) => {
                errors += 1;
                eprintln!("request failed: {e}");
            }
        }
    }
    println!("\n== end-to-end serving results ==");
    println!("requests:   {n_requests} ({errors} errors)");
    println!("accuracy:   {:.1}%", 100.0 * correct as f64 / n_requests as f64);
    println!("throughput: {:.2} problems/s", n_requests as f64 / wall);
    println!(
        "latency ms: p50 {:.0}  p95 {:.0}  mean {:.0}",
        stats::quantile(&latencies, 0.5),
        stats::quantile(&latencies, 0.95),
        stats::mean(&latencies)
    );
    println!("flops/req:  {:.3e}", flops_total / n_requests as f64);
    println!("\nserver metrics:\n{}", metrics.render());
    handle.shutdown();
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    Ok(())
}

fn route(
    handle: &EngineHandle,
    metrics: &Metrics,
    defaults: &SearchConfig,
    req: http::Request,
) -> http::Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => http::Response::json(200, "{\"ok\":true}".into()),
        ("GET", "/metrics") => http::Response::text(200, &metrics.render()),
        ("POST", "/solve") => {
            let t0 = Instant::now();
            let parsed = match api::parse_solve(&req.body, defaults) {
                Ok(p) => p,
                Err(e) => {
                    metrics.record_error();
                    return http::Response::json(400, format!("{{\"error\":\"{e}\"}}"));
                }
            };
            match handle.solve(parsed.clone(), defaults.clone()) {
                Ok(out) => {
                    metrics.record_ok(
                        t0.elapsed().as_secs_f64() * 1000.0,
                        out.ledger.total_flops(),
                        out.correct,
                    );
                    http::Response::json(200, api::render_solve(&parsed, &out))
                }
                Err(e) => {
                    metrics.record_error();
                    http::Response::json(500, format!("{{\"error\":\"{e}\"}}"))
                }
            }
        }
        _ => http::Response::json(404, "{\"error\":\"not found\"}".into()),
    }
}
