//! End-to-end serving driver (the mandated e2e validation): starts the
//! HTTP server on a real socket in front of an engine shard pool, loads
//! the trained LM + PRM through the PJRT runtime (one engine per shard),
//! fires concurrent /solve requests from client threads, and reports
//! accuracy, latency percentiles, throughput and per-shard utilization.
//!
//! By default it runs the same workload twice — `--shards-list 1,4` —
//! and reports the scaling ratio, which is the acceptance gate for the
//! shard-pool refactor (>2x at 4 shards on >=4 cores).
//!
//!     make artifacts && cargo run --release --example serve_benchmark -- \
//!         --requests 32 --clients 8 --shards-list 1,4 --cache 0
//!
//! `--cache N` enables the pool's LRU solve cache (0, the default here,
//! keeps it off so the ratio measures engine throughput, not cache hits).
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end serving.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Instant;

use erprm::config::SearchConfig;
use erprm::server::{http, metrics::Metrics, route, router::EnginePool, Lifecycle};
use erprm::tokenizer as tk;
use erprm::util::cli::Args;
use erprm::util::json::Json;
use erprm::util::rng::Rng;
use erprm::util::stats;
use erprm::util::threadpool::ThreadPool;
use erprm::workload::{gen_problem, SATMATH};

fn post_solve(addr: std::net::SocketAddr, body: &str) -> Result<(u16, Json), String> {
    let mut s = TcpStream::connect(addr).map_err(|e| e.to_string())?;
    let req = format!(
        "POST /solve HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    s.write_all(req.as_bytes()).map_err(|e| e.to_string())?;
    let mut out = String::new();
    s.read_to_string(&mut out).map_err(|e| e.to_string())?;
    let status: u16 = out
        .strip_prefix("HTTP/1.1 ")
        .and_then(|r| r.get(..3))
        .and_then(|c| c.parse().ok())
        .ok_or("bad status line")?;
    let body = out.split("\r\n\r\n").nth(1).ok_or("no body")?;
    let json = Json::parse(body).map_err(|e| e.to_string())?;
    Ok((status, json))
}

struct RunReport {
    shards: usize,
    throughput_rps: f64,
    accuracy_pct: f64,
    p50_ms: f64,
    p95_ms: f64,
    errors: usize,
    shard_solves: Vec<u64>,
    /// Early-rejection ledger from the pool's per-request trace recorder:
    /// beams rejected and the estimated FLOPs those rejections saved.
    er_beams_rejected: u64,
    er_flops_saved: f64,
}

/// Run the full workload against a fresh pool with `shards` shards and
/// return the measured report.
fn run_once(
    shards: usize,
    capacity: usize,
    cache: usize,
    clients: usize,
    bodies: &[String],
) -> Result<RunReport, Box<dyn std::error::Error>> {
    let defaults = SearchConfig { n_beams: 8, tau: 8, ..SearchConfig::default() };
    let pool = EnginePool::spawn("artifacts".into(), shards, capacity, cache)?;
    let metrics = Arc::new(Metrics::default());
    let http_pool = ThreadPool::new(clients.max(2));
    let stop = Arc::new(AtomicBool::new(false));
    let p2 = pool.clone();
    let m2 = Arc::clone(&metrics);
    let d2 = defaults.clone();
    let l2 = Lifecycle::new();
    let addr = http::serve(
        "127.0.0.1:0",
        &http_pool,
        1 << 20,
        Arc::clone(&stop),
        Arc::new(move |req| route(&p2, &m2, &d2, &l2, req)),
    )?;

    let client_pool = ThreadPool::new(clients);
    let t0 = Instant::now();
    let results = erprm::util::threadpool::parallel_map(
        &client_pool,
        bodies.to_vec(),
        move |body| {
            let t = Instant::now();
            let resp = post_solve(addr, &body);
            (t.elapsed().as_secs_f64() * 1000.0, resp)
        },
    );
    let wall = t0.elapsed().as_secs_f64();

    let mut latencies = Vec::new();
    let mut correct = 0usize;
    let mut errors = 0usize;
    for (ms, resp) in &results {
        latencies.push(*ms);
        match resp {
            Ok((200, j)) => {
                correct += (j.get("correct").and_then(Json::as_bool) == Some(true)) as usize;
            }
            Ok((status, _)) => {
                errors += 1;
                eprintln!("request rejected: HTTP {status}");
            }
            Err(e) => {
                errors += 1;
                eprintln!("request failed: {e}");
            }
        }
    }
    let report = RunReport {
        shards: pool.n_shards(),
        throughput_rps: bodies.len() as f64 / wall,
        accuracy_pct: 100.0 * correct as f64 / bodies.len() as f64,
        p50_ms: stats::quantile(&latencies, 0.5),
        p95_ms: stats::quantile(&latencies, 0.95),
        errors,
        shard_solves: pool.shard_solves(),
        er_beams_rejected: pool.tracer().totals().er_beams_rejected,
        er_flops_saved: pool.tracer().totals().er_flops_saved,
    };
    println!(
        "\nserver metrics ({shards} shard run):\n{}{}",
        metrics.render(),
        pool.render_metrics()
    );
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    pool.shutdown();
    Ok(report)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    erprm::util::logging::init_from_env();
    let args = Args::from_env()?;
    let n_requests = args.get_usize("requests", 16)?;
    let clients = args.get_usize_min("clients", 8, 1)?;
    let capacity = args.get_usize_min("capacity", 64, 1)?;
    let cache = args.get_usize("cache", 0)?;
    let shards_list = args.get_usize_list("shards-list", &[1, 4])?;

    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("artifacts missing; run `make artifacts` first (skipping benchmark)");
        return Ok(());
    }

    // One shared workload so every shard count sees identical requests.
    let mut rng = Rng::new(314);
    let bodies: Vec<String> = (0..n_requests)
        .map(|_| {
            let p = gen_problem(&mut rng, &SATMATH);
            let ops: Vec<String> = p
                .ops
                .iter()
                .map(|s| {
                    format!(
                        "[\"{}\",{}]",
                        match s.op {
                            tk::PLUS => "+",
                            tk::MINUS => "-",
                            _ => "*",
                        },
                        s.d
                    )
                })
                .collect();
            format!(
                "{{\"v0\": {}, \"ops\": [{}], \"mode\": \"er\", \"n_beams\": 8, \"tau\": 8}}",
                p.v0,
                ops.join(",")
            )
        })
        .collect();

    println!(
        "firing {n_requests} requests from {clients} client threads at shard counts {shards_list:?}"
    );
    let mut reports = Vec::new();
    for &shards in &shards_list {
        reports.push(run_once(shards, capacity, cache, clients, &bodies)?);
    }

    println!("\n== end-to-end serving results ==");
    println!(
        "{:<8} {:>12} {:>10} {:>9} {:>9} {:>7}  per-shard solves",
        "shards", "throughput/s", "accuracy%", "p50 ms", "p95 ms", "errors"
    );
    for r in &reports {
        println!(
            "{:<8} {:>12.2} {:>10.1} {:>9.0} {:>9.0} {:>7}  {:?}",
            r.shards, r.throughput_rps, r.accuracy_pct, r.p50_ms, r.p95_ms, r.errors,
            r.shard_solves
        );
    }
    println!("\nearly-rejection ledger (from request traces):");
    for r in &reports {
        println!(
            "  {} shard(s): {} beams rejected, est FLOPs saved {}",
            r.shards,
            r.er_beams_rejected,
            erprm::util::benchkit::fmt_flops(r.er_flops_saved)
        );
    }
    if reports.len() >= 2 {
        let base = &reports[0];
        let best = &reports[reports.len() - 1];
        let ratio = best.throughput_rps / base.throughput_rps.max(1e-9);
        println!(
            "\nscaling: {} shard(s) -> {} shard(s) = {ratio:.2}x request throughput",
            base.shards, best.shards
        );
    }
    Ok(())
}
